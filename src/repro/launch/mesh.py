"""Production mesh construction (task brief, MULTI-POD DRY-RUN §1).

A FUNCTION, not a module-level constant: importing this module never touches
JAX device state, so tests/benches see one CPU device unless dryrun.py set
XLA_FLAGS first.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(dp: int = 1, tp: int = 1):
    """Small mesh over the real local devices (tests / examples)."""
    return jax.make_mesh((dp, tp), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (pod folds into DP when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None
