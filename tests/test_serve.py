"""Serving-path tests: per-step continuous batching (slot reuse mid-stream,
zero steady-state padded slots), the slot/state-surgery contract across all
four decode families, cost-model admission, SLA/deadline accounting, and
real-token-only throughput."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import main
from repro.serve import (CostModelAdmission, Request, SamplingConfig,
                         Scheduler, ServeEngine, take_slot, validate_donor)


def _requests(cfg, gen_lens, prompt_len=8, seed=0, sla_s=None):
    rng = np.random.default_rng(seed)
    return [
        Request(rid=f"r{i}",
                tokens=rng.integers(0, cfg.vocab, prompt_len).astype(np.int32),
                gen_len=g, sla_s=sla_s)
        for i, g in enumerate(gen_lens)
    ]


# -- scheduler control plane (no models, no jax) -------------------------------


def test_scheduler_slot_lifecycle_and_sla_accounting():
    sched = Scheduler(2)
    a = Request(rid="a", tokens=np.arange(4), gen_len=3, sla_s=10.0)
    b = Request(rid="b", tokens=np.arange(4), gen_len=2, sla_s=0.5)
    sched.submit(a, 0.0)
    sched.submit(b, 0.0)
    assert sched.free_slots() == [0, 1]

    req = sched.next_admissible(0.0)
    sched.place(req, 0, step=0)
    sched.first_token(0, 1.0)                  # TTFT = 1s
    assert sched.free_slots() == [1]
    sched.step_done(0)
    sched.step_done(0)                         # 3 tokens total -> done
    assert sched.slot_done(0)
    m = sched.finish(0, 3.0)
    assert m.rid == "a" and m.ttft_s == pytest.approx(1.0)
    assert m.latency_s == pytest.approx(3.0) and m.sla_met is True
    assert m.decode_tokens_per_s == pytest.approx(2 / 2.0)
    assert sched.free_slots() == [0, 1]        # slot freed for reuse

    req = sched.next_admissible(0.0)
    sched.place(req, 0, step=5)
    sched.first_token(0, 0.2)
    sched.step_done(0)
    m = sched.finish(0, 1.0)                   # 1.0s > sla 0.5s -> miss
    assert m.sla_met is False
    assert sched.sla_hit_rate() == pytest.approx(0.5)
    assert sched.slot_reuse() == [2, 0]
    assert [e["rid"] for e in sched.admission_log] == ["a", "b"]


def test_cost_model_admission_refuses_over_budget_and_infeasible():
    cfg = get_config("qwen1.5-0.5b").reduced()
    adm = CostModelAdmission(cfg, batch=2, max_len=32)
    # roofline terms are real numbers fed by lib.cost()
    assert adm.decode_bytes_per_step() > adm.param_bytes > 0
    assert adm.step_seconds() > 0

    fits = Request(rid="ok", tokens=np.arange(8), gen_len=8, sla_s=60.0)
    assert adm.admit(fits, 0.0) == (True, "ok")
    over = Request(rid="big", tokens=np.arange(30), gen_len=8)
    ok, reason = adm.admit(over, 0.0)
    assert not ok and reason.startswith("over_budget")
    doomed = Request(rid="tight", tokens=np.arange(8), gen_len=8, sla_s=1e-12)
    ok, reason = adm.admit(doomed, 0.0)
    assert not ok and reason.startswith("sla_infeasible")

    # the scheduler records refusals and keeps serving admissible work
    sched = Scheduler(2, admission=adm)
    sched.submit(over, 0.0)
    sched.submit(fits, 0.0)
    got = sched.next_admissible(0.0)
    assert got.rid == "ok"
    assert [r.rid for r in sched.refused] == ["big"]
    assert "over_budget" in sched.refused[0].reason


# -- per-step continuous batching through the engine ---------------------------


@pytest.mark.parametrize("arch,enc_len", [("qwen1.5-0.5b", None),
                                          ("rwkv6-7b", None),
                                          ("zamba2-7b", None),
                                          ("whisper-tiny", 8)])
def test_engine_admits_into_freed_slot_mid_stream(arch, enc_len):
    """batch=2, requests=4, staggered gen lengths: a freed slot must be
    refilled BEFORE the long-running neighbour finishes, across all four
    decode-state families (KV cache, recurrent, hybrid, encdec)."""
    import jax

    jax.clear_caches()
    cfg = get_config(arch).reduced()
    eng = ServeEngine(cfg, batch=2, max_len=24, enc_len=enc_len)
    gen_lens = [4, 12, 6, 12]
    rep = eng.run(_requests(cfg, gen_lens, sla_s=600.0))

    assert rep["requests"] == 4
    # throughput counts only real tokens (idle slots are never traffic)
    assert rep["generated_tokens"] == sum(gen_lens)
    assert rep["decode_tokens_per_s"] > 0
    # per-request metrics: TTFT, decode t/s, SLA
    assert all(m["ttft_s"] > 0 for m in rep["per_request"])
    assert all(m["decode_tokens_per_s"] > 0 for m in rep["per_request"])
    assert rep["sla_hit_rate"] == 1.0
    # steady state ran with zero padded slots
    assert rep["padded_slot_steps_steady"] == 0
    # slot reuse: some slot served more than one request
    assert max(rep["slot_reuse"]) >= 2
    # r2 entered a freed slot strictly mid-stream: after step 0, before the
    # long request admitted at step 0 (gen 12) could possibly have finished
    steps_by_rid = {e["rid"]: e["step"] for e in rep["admission_log"]}
    assert 0 < steps_by_rid["r2"] < 12 - 1, steps_by_rid


def test_engine_refuses_and_still_serves_the_rest():
    cfg = get_config("qwen1.5-0.5b").reduced()
    eng = ServeEngine(cfg, batch=2, max_len=16)
    good = _requests(cfg, [3, 3], prompt_len=6)
    bad = [Request(rid="big", tokens=np.zeros(14, np.int32), gen_len=8),
           Request(rid="doomed", tokens=np.zeros(6, np.int32), gen_len=3,
                   sla_s=1e-12)]
    rep = eng.run(good + bad)
    assert rep["requests"] == 2
    reasons = {r["rid"]: r["reason"] for r in rep["refused"]}
    assert reasons["big"].startswith("over_budget")
    assert reasons["doomed"].startswith("sla_infeasible")
    assert rep["generated_tokens"] == 6


def test_engine_gen_len_one_does_not_strand_the_queue():
    """Requests finishing AT admission (gen_len=1) free their slots with no
    active decode; the loop must re-enter admission, not exit early."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    # prompt 4 pads to bucket 8: size the table for bucket + gen, not prompt
    eng = ServeEngine(cfg, batch=2, max_len=16)
    rep = eng.run(_requests(cfg, [1, 1, 1], prompt_len=4))
    assert rep["requests"] == 3
    assert rep["generated_tokens"] == 3

    # a slot freed DURING the admission phase is refilled in the same phase:
    # no padded decode step while the queue still has work
    rep = eng.run(_requests(cfg, [1, 6, 2], prompt_len=4))
    assert rep["requests"] == 3
    assert rep["padded_slot_steps_steady"] == 0


def test_engine_vlm_accounts_vision_prefix():
    """VLM prefill prepends vision_prefix cache rows: decode must write after
    them (not clobber them), and admission must budget for them."""
    cfg = get_config("internvl2-2b").reduced()
    assert cfg.vision_prefix > 0
    max_len = cfg.vision_prefix + 8 + 4       # prompt 6 pads to bucket 8
    eng = ServeEngine(cfg, batch=2, max_len=max_len)
    reqs = _requests(cfg, [3, 4, 3], prompt_len=6)
    # per-request media rides along (others fall back to zero embeddings)
    reqs[0].embeds = np.ones((cfg.vision_prefix, cfg.d_model), np.float32)
    rep = eng.run(reqs)
    assert rep["requests"] == 3
    assert rep["generated_tokens"] == 10
    # bucket alone fits max_len, but bucket + vision prefix + gen does not
    from repro.serve import BucketPolicy
    adm = CostModelAdmission(cfg, batch=2, max_len=max_len,
                             policy=BucketPolicy((8, 16), 8))
    tight = Request(rid="t", tokens=np.zeros(9, np.int32), gen_len=4)
    ok, reason = adm.admit(tight, 0.0)
    assert not ok and "vision prefix" in reason


def test_engine_rejects_duplicate_rids_and_empty_gen():
    cfg = get_config("qwen1.5-0.5b").reduced()
    eng = ServeEngine(cfg, batch=2, max_len=12)
    dup = [Request(rid="same", tokens=np.zeros(4, np.int32), gen_len=2),
           Request(rid="same", tokens=np.zeros(4, np.int32), gen_len=2)]
    with pytest.raises(ValueError, match="duplicate request rids"):
        eng.run(dup)
    with pytest.raises(ValueError, match="gen_len"):
        eng.run([Request(rid="z", tokens=np.zeros(4, np.int32), gen_len=0)])


def test_engine_sampling_temperature_top_k():
    cfg = get_config("qwen1.5-0.5b").reduced()
    eng = ServeEngine(cfg, batch=2, max_len=16,
                      sampling=SamplingConfig(temperature=0.8, top_k=16),
                      seed=3)
    rep = eng.run(_requests(cfg, [4, 4, 4], prompt_len=6))
    assert rep["requests"] == 3
    toks = [t for out in rep["outputs"].values() for t in out]
    assert len(toks) == 12
    # sampler masks the padded-vocab columns: only REAL token ids come out
    assert all(0 <= t < cfg.vocab for t in toks)


def test_per_slot_decode_matches_solo_reference():
    """The continuous-batching path (vector pos: per-slot RoPE, vmapped cache
    scatter, (B,) kv_len mask) must reproduce a solo scalar-pos generation
    token for token — for a request admitted MID-STREAM into a slot whose
    neighbour sits at a different position."""
    import jax
    import jax.numpy as jnp

    jax.clear_caches()
    cfg = get_config("qwen1.5-0.5b").reduced()
    from repro.nn.model import build_model

    max_len, gen = 24, 6
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))   # same seed as the engine
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
               for _ in range(3)]
    target = prompts[2]

    def greedy(logits):
        masked = np.asarray(logits, np.float64)[..., :cfg.vocab]
        return int(masked.argmax(-1)[0])

    # solo reference: scalar-pos decode, batch 1
    logits, st = model.prefill(
        params, {"tokens": jnp.asarray(target[None])}, max_len)
    want = [greedy(logits)]
    pos = len(target)
    for _ in range(gen - 1):
        tok = jnp.asarray([[want[-1]]], jnp.int32)
        logits, st = model.decode_step(params, st, tok, jnp.int32(pos))
        want.append(greedy(logits))
        pos += 1

    # engine: the target request rides a freed slot mid-stream (slot 0 frees
    # at step 3 while slot 1 is still at its own, different position)
    eng = ServeEngine(cfg, batch=2, max_len=max_len, seed=0)
    reqs = [Request(rid="filler0", tokens=prompts[0], gen_len=4),
            Request(rid="filler1", tokens=prompts[1], gen_len=12),
            Request(rid="target", tokens=target, gen_len=gen)]
    rep = eng.run(reqs)
    steps_by_rid = {e["rid"]: e["step"] for e in rep["admission_log"]}
    assert steps_by_rid["target"] > 0          # genuinely mid-stream
    assert rep["outputs"]["target"] == want


# -- slot surgery across all four decode families ------------------------------


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "zamba2-7b", "rwkv6-7b",
                                  "whisper-tiny"])
def test_slot_surgery_insert_take_reset(arch):
    """insert_slot grafts a batch-1 prefilled state into one slot without
    touching neighbours; reset_slot zeroes exactly that slot."""
    import jax
    import jax.numpy as jnp

    jax.clear_caches()
    cfg = get_config(arch).reduced()
    from repro.nn.model import build_model

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len, prompt_len = 12, 4
    enc_len = 8 if cfg.family == "audio" else None
    state = model.init_decode_state(2, max_len, enc_len=enc_len)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (1, prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros(
            (1, cfg.vision_prefix, cfg.d_model), cfg.dtype)
    if cfg.family == "audio":
        batch["audio_embeds"] = jnp.ones((1, enc_len, cfg.d_model), cfg.dtype)
    _, donor = model.prefill(params, batch, max_len)

    axes = model.state_batch_axes(state)
    validate_donor(state, donor, axes)
    st1 = model.insert_slot(state, donor, 1)
    for got, want in zip(jax.tree.leaves(take_slot(st1, axes, 1)),
                         jax.tree.leaves(donor)):
        np.testing.assert_allclose(np.asarray(got, np.float64),
                                   np.asarray(want, np.float64))
    # neighbour slot untouched
    for got, want in zip(jax.tree.leaves(take_slot(st1, axes, 0)),
                         jax.tree.leaves(take_slot(state, axes, 0))):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # reset zeroes exactly the grafted slot
    st2 = model.reset_slot(st1, 1)
    assert all(np.abs(np.asarray(x)).max() == 0
               for x in jax.tree.leaves(take_slot(st2, axes, 1)))


def test_validate_donor_rejects_shape_mismatch():
    import jax

    jax.clear_caches()
    cfg = get_config("qwen1.5-0.5b").reduced()
    from repro.nn.model import build_model

    model = build_model(cfg)
    state = model.init_decode_state(2, 16)
    wrong = model.init_decode_state(1, 12)      # padded to the wrong max_len
    with pytest.raises(ValueError, match="incompatible"):
        validate_donor(state, wrong, model.state_batch_axes(state))


# -- CLI facade ----------------------------------------------------------------


def test_serve_cli_counts_only_real_requests():
    # 5 requests with batch 4: the 5th rides a freed slot, and throughput
    # counts served requests only (idle slots are compute, not traffic)
    result = main(["--arch", "qwen1.5-0.5b", "--reduced", "--batch", "4",
                   "--prompt-len", "8", "--gen-len", "4", "--requests", "5"])
    assert result["requests"] == 5
    assert result["decode_tokens_per_s"] > 0
    assert result["padded_slot_steps_steady"] == 0
    assert result["refused"] == []
    assert len(result["sample_output"]) == 4


def test_serve_cli_sampling_and_sla_flags():
    result = main(["--arch", "qwen1.5-0.5b", "--reduced", "--batch", "2",
                   "--prompt-len", "6", "--gen-len", "3", "--requests", "3",
                   "--temperature", "0.9", "--top-k", "8",
                   "--sla-ms", "600000"])
    assert result["requests"] == 3
    assert result["sla_hit_rate"] == 1.0
