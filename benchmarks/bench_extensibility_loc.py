"""Paper §5.3 extensibility accounting: LOC written vs LOC generated.

Paper: FPGA target = 19 LOC schema/template changes + ~100 LOC of UPD ->
3581 LOC generated. Here: each target is UPD-only (0 core-code lines); we
report UPD lines vs generated package lines per target.
"""

from __future__ import annotations

from repro.core import GenConfig, generate_library
from repro.core.loader import DEFAULT_UPD_ROOT

from .common import emit


def _upd_lines_for_target(target: str) -> tuple[int, int]:
    tgt_file = DEFAULT_UPD_ROOT / "targets" / f"{target}.yaml"
    tgt_lines = len(tgt_file.read_text().splitlines()) if tgt_file.exists() else 0
    prim_lines = 0
    for f in (DEFAULT_UPD_ROOT / "primitives").glob("*.yaml"):
        for block in f.read_text().split("\n---"):
            if target in block:
                prim_lines += len(block.splitlines())
    return tgt_lines, prim_lines


def run() -> list[str]:
    out = []
    for target in ("cpu_xla", "pallas_interpret", "tpu_v5e"):
        pkg_dir, _ = generate_library(GenConfig(target=target))
        gen_lines = sum(len(p.read_text().splitlines())
                        for p in pkg_dir.rglob("*.py"))
        tgt_lines, prim_lines = _upd_lines_for_target(target)
        emit(f"loc_{target}", 0,
             f"target_yaml={tgt_lines} prim_yaml~={prim_lines} "
             f"generated_py={gen_lines} core_changes=0")
        out.append(f"{target}: {tgt_lines}+{prim_lines} UPD -> {gen_lines} generated")
    return out


if __name__ == "__main__":
    run()
