"""Benchmark-driven adaptive variant selection GPO — BEYOND PAPER.

Paper §4.2: *"we recommend benchmarking all variants within the generation
process and choosing the best-performing one [...] benchmarking alongside
adaptive variant selection should be integrated as an ongoing process."*
The paper leaves this as future work; we implement it.

For every primitive with >1 valid candidate and a ``bench`` setup in its UPD,
each candidate body is stage-1 rendered, exec'd into a scratch namespace,
jit-compiled, and timed on the live host. Measured winners override the flag
heuristic (``Selection.reason == "bench"``). Winners live in the unified
artifact cache, content-addressed by (UPD fingerprint, target, probed
hardware flags, generator version) — moving the cache to different hardware
or editing the corpus re-benchmarks automatically, editing nothing makes
repeated generation free ("ongoing process").
"""

from __future__ import annotations

import time

from . import engine
from .cache import ArtifactCache
from .model import GenerationResult, Selection
from .select import hardware_flags, score, valid_candidates

_PRELUDE = (
    "import jax\nimport jax.numpy as jnp\nimport numpy as np\nfrom jax import lax\n"
)


def _bench_store(ctx: GenerationResult) -> ArtifactCache:
    from .library import DEFAULT_BUILD_ROOT, artifact_key, resolve_store

    key = artifact_key(ctx.config, ctx.meta.get("fingerprint", "x"),
                       ctx.corpus)
    store, _ = resolve_store(ctx.config, key,
                             ctx.config.build_root or DEFAULT_BUILD_ROOT)
    return store


def _compile_candidate(ctx: GenerationResult, prim, impl, ctype: str):
    """exec a candidate implementation into a scratch module namespace."""
    sru = ctx.targets[impl.target_extension].as_render_dict()
    body = engine.render_stage1(impl.implementation, sru=sru, ctype=ctype,
                                primitive=prim.name, params=prim.arg_names())
    helpers = ""
    if impl.helpers.strip():
        helpers = engine.render_stage1(impl.helpers, sru=sru, ctype=ctype,
                                       primitive=prim.name, params=prim.arg_names())
    sig = prim.signature()
    indented = "\n".join("    " + ln if ln.strip() else ln
                         for ln in body.splitlines())
    src = f"{_PRELUDE}\n{helpers}\n\ndef __cand__({sig}):\n{indented}\n"

    class _Tgt:  # minimal TARGET stand-in for helper code
        pass

    for k, v in sru.items():
        setattr(_Tgt, k, v)
    ns: dict = {"TARGET": _Tgt}
    exec(src, ns)  # noqa: S102 — trusted UPD, same trust domain as the repo
    return ns["__cand__"]


def _time_candidate(fn, args: dict, n_iter: int) -> float:
    import jax

    jfn = jax.jit(fn)
    out = jfn(**args)
    jax.block_until_ready(out)  # compile + warmup
    t0 = time.perf_counter()
    for _ in range(n_iter):
        out = jfn(**args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n_iter


class BenchSelectGPO:
    name = "bench-select"

    def run(self, ctx: GenerationResult) -> GenerationResult:
        if ctx.errors:
            return ctx
        tgt = ctx.targets[ctx.config.target]
        if not tgt.runs_on_host:
            ctx.warn("bench-select: target does not run on this host; skipped")
            return ctx
        hw = hardware_flags(ctx)
        from .library import artifact_key

        store = _bench_store(ctx)
        store_key = artifact_key(ctx.config, ctx.meta.get("fingerprint", "x"),
                                 ctx.corpus)
        cache = store.bench_load(store_key)

        for name, sels in ctx.selection.items():
            prim = ctx.primitives[name]
            if prim.bench is None:
                continue
            for ctype in list(sels):
                cands = valid_candidates(prim, ctx.config.target, ctype, hw)
                if len(cands) < 2:
                    continue
                key = f"{name}/{ctype}"
                # smoke mode: one timed iteration — exercises the full
                # compile+measure path without the measurement cost (CI)
                n_iter = 1 if ctx.config.bench_smoke else prim.bench["n_iter"]
                cached = cache.get(key)
                # a cached winner measured with FEWER iterations than requested
                # is stale (a smoke sweep must never pin real selection)
                if cached is not None and cached.get("n_iter", 0) >= n_iter:
                    winner_idx = cached["winner"]
                else:
                    # build sample inputs from the UPD bench setup
                    sru = tgt.as_render_dict()
                    setup_src = engine.render_stage1(
                        prim.bench["setup"], sru=sru, ctype=ctype,
                        primitive=name, params=prim.arg_names())
                    ns: dict = {}
                    exec(_PRELUDE + "\n" + setup_src, ns)  # noqa: S102
                    args = ns["args"]
                    times = []
                    for impl in cands:
                        try:
                            fn = _compile_candidate(ctx, prim, impl, ctype)
                            t = _time_candidate(fn, args, n_iter)
                        except Exception as e:  # candidate broken on host
                            ctx.warn(f"bench-select {key}: candidate failed ({e})")
                            t = float("inf")
                        times.append(t)
                    winner_idx = prim.definitions.index(
                        cands[times.index(min(times))])
                    cache[key] = {
                        "winner": winner_idx,
                        "times_us": [t * 1e6 for t in times],
                        "candidates": [prim.definitions.index(c) for c in cands],
                        "n_iter": n_iter,
                    }
                impl = prim.definitions[winner_idx]
                if sels[ctype].impl is not impl:
                    sels[ctype] = Selection(
                        primitive=name, target=ctx.config.target, ctype=ctype,
                        impl=impl, score=score(impl, hw),
                        candidates=len(cands), reason="bench",
                    )
                else:
                    sels[ctype].reason = "bench"
        ctx.meta["bench_cache"] = str(store.bench_store(store_key, cache))
        return ctx
