"""Train-step builder: loss + grad + AdamW update, with optional microbatch
gradient accumulation (scan) and int8 error-feedback gradient compression.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.model import Model

from . import optimizer as opt_mod
from .optimizer import OptConfig


def make_train_step(model: Model, opt_cfg: OptConfig, *,
                    microbatches: int = 1, compress_grads: bool = False,
                    mesh=None):
    """Returns train_step(train_state, batch) -> (train_state, metrics).

    train_state = {"params", "opt"}; batch = {"tokens", "labels", ...}.
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat=True)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        # split the leading batch dim into microbatches and scan-accumulate
        def reshape(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mb = jax.tree.map(reshape, batch)

        def body(acc, micro):
            (loss, metrics), grads = grad_fn(params, micro)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(jnp.add, acc_g, grads)
            return (acc_g, acc_l + loss), metrics

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), metrics = jax.lax.scan(body, (zero_g, 0.0), mb)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / microbatches, metrics, grads

    def _pin_like_params(tree):
        """Constrain a params-shaped pytree (params, grads, float moments)
        to the ``dist.sharding`` parameter rules so compiled outputs carry
        the SAME shardings the inputs arrived with — step N+1 then consumes
        step N's donated buffers with zero resharding. Identity off-mesh."""
        if mesh is None:
            return tree
        from repro.dist import sharding as dist_sharding

        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            dist_sharding.param_shardings(mesh, tree))

    def train_step(train_state, batch):
        params, opt_state = train_state["params"], train_state["opt"]
        loss, metrics, grads = compute_grads(params, batch)
        if compress_grads:
            from repro.dist.compression import compress_decompress
            grads, cerr = compress_decompress(grads)
            metrics = {**metrics, "compress_err": cerr}
        new_params, new_opt, opt_metrics = opt_mod.apply_updates(
            opt_cfg, params, grads, opt_state)
        new_params = _pin_like_params(new_params)
        if mesh is not None and opt_cfg.moment_dtype != "int8":
            # float moments mirror the parameter tree leaf-for-leaf; int8
            # moments are (q, scale) pairs with their own treedef — those
            # stay wherever the update computed them
            new_opt = {**new_opt, "m": _pin_like_params(new_opt["m"]),
                       "v": _pin_like_params(new_opt["v"])}
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, **metrics, **opt_metrics})

    return train_step


def init_train_state(model: Model, opt_cfg: OptConfig, key, *, mesh=None):
    """Fresh {params, opt} state; with ``mesh`` the params AND the float
    optimizer moments are placed by the ``dist.sharding`` parameter rules
    (row/col TP + output-projection flip), matching what the mesh-aware
    train step pins — so the very first step already runs reshard-free."""
    params = model.init(key)
    opt = opt_mod.init_opt_state(opt_cfg, params)
    if mesh is not None:
        from repro.dist import sharding as dist_sharding

        shardings = dist_sharding.param_shardings(mesh, params)
        params = jax.device_put(params, shardings)
        if opt_cfg.moment_dtype != "int8":
            opt = {**opt, "m": jax.device_put(opt["m"], shardings),
                   "v": jax.device_put(opt["v"], shardings)}
    return {"params": params, "opt": opt}
