"""Corpus pipeline: load + template-check + validate the UPD once per
fingerprint (the corpus half of the corpus/target split).

The paper re-runs the whole pipeline per invocation; with many targets that
means re-parsing and re-validating an identical corpus N times.  Here the
corpus phase produces an immutable :class:`~.model.CorpusIR` memoised on the
UPD content fingerprint, so ``generate_all(targets)`` validates exactly once
and a fingerprint change (edited UPD document, template, or generator source)
transparently rebuilds it — incremental invalidation, paper §4.2 "ongoing
process".
"""

from __future__ import annotations

from . import loader
from .model import CorpusBuild, CorpusIR
from .pipeline import GenerationError, OperatorList, TemplateCheckGPO


class CorpusPipeline(OperatorList):
    """Corpus-phase pipeline: target-agnostic GPOs only."""

    def __init__(self, operators=None):
        if operators is None:
            from .validate import ValidateGPO

            operators = [TemplateCheckGPO(), ValidateGPO()]
        super().__init__(operators)

    def build(self, upd_paths: tuple[str, ...] = (), *,
              fingerprint: str | None = None, strict: bool = True) -> CorpusIR:
        cb = CorpusBuild(upd_paths=tuple(upd_paths))
        cb.raw_targets = loader.load_raw_targets(cb.upd_paths)
        cb.raw_primitives = loader.load_raw_primitives(cb.upd_paths)
        cb.fingerprint = fingerprint or loader.upd_fingerprint(cb.upd_paths)
        for op in self.operators:
            cb = op.run(cb)
            if cb.errors and strict:
                raise GenerationError(cb.errors, cb.warnings)
        return cb.freeze()


# fingerprint-keyed corpus memo: validation runs once per distinct UPD content
_CORPUS_CACHE: dict[tuple[str, tuple[str, ...]], CorpusIR] = {}


def load_corpus(upd_paths: tuple[str, ...] = (), *,
                fingerprint: str | None = None,
                force: bool = False) -> CorpusIR:
    """Return the validated corpus for ``upd_paths``, building it at most once
    per content fingerprint. Editing any UPD/template/generator file changes
    the fingerprint and forces a rebuild; everything else is a memo hit.

    ``fingerprint`` lets callers that already hashed the UPD tree (e.g. the
    artifact-key computation) skip re-hashing it for the memo key."""
    upd_paths = tuple(upd_paths)
    if fingerprint is None:
        fingerprint = loader.upd_fingerprint(upd_paths)
    key = (fingerprint, upd_paths)
    if not force and key in _CORPUS_CACHE:
        return _CORPUS_CACHE[key]
    corpus = CorpusPipeline().build(upd_paths, fingerprint=fingerprint)
    _CORPUS_CACHE[key] = corpus
    return corpus


def corpus_cache_clear() -> None:
    _CORPUS_CACHE.clear()
