"""Selection-heuristic tests incl. hypothesis property tests (paper §3.2 ②)."""

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import GenConfig
from repro.core.model import CorpusIR, GenerationResult, ImplDef, ParamDef, PrimitiveDef
from repro.core.select import SelectGPO, choose, score, valid_candidates


def _prim(defs):
    return PrimitiveDef(
        name="p", group="g", brief="", parameters=(ParamDef("a"),),
        returns_ctype="register", definitions=tuple(defs))


def _impl(target="t", ctypes=("float32",), flags=(), body="return a",
          native=True):
    return ImplDef(target_extension=target, ctypes=tuple(ctypes),
                   flags=tuple(flags), implementation=body, is_native=native)


def test_flag_subset_required():
    prim = _prim([_impl(flags=("xla", "exotic"))])
    assert valid_candidates(prim, "t", "float32", frozenset({"xla"})) == []
    assert len(valid_candidates(prim, "t", "float32",
                                frozenset({"xla", "exotic"}))) == 1


def test_more_flags_wins():
    """Paper: more hardware capabilities used => more specialized => wins."""
    generic = _impl(flags=("xla",), body="return a  # generic")
    special = _impl(flags=("xla", "mxu", "vmem"), body="return a  # special")
    sel = choose(_prim([generic, special]), "t", "float32",
                 frozenset({"xla", "mxu", "vmem"}))
    assert sel.impl is special
    assert sel.candidates == 2


def test_loc_tiebreak_shortest_wins():
    """Paper: equal score -> ascending lines of code, first (shortest) wins."""
    long_ = _impl(flags=("xla",), body="x = a\ny = x\nreturn y")
    short = _impl(flags=("xla",), body="return a")
    sel = choose(_prim([long_, short]), "t", "float32", frozenset({"xla"}))
    assert sel.impl is short


def test_hardware_override_changes_selection():
    """Paper §4.1: the generator can be 'tricked' into assuming hardware."""
    generic = _impl(flags=("xla",))
    special = _impl(flags=("xla", "bmi2"), body="return a  # pext")
    prim = _prim([generic, special])
    assert choose(prim, "t", "float32", frozenset({"xla"})).impl is generic
    assert choose(prim, "t", "float32",
                  frozenset({"xla", "bmi2"})).impl is special


@settings(max_examples=200, deadline=None)
@given(
    hw=st.frozensets(st.sampled_from("abcdefgh"), max_size=8),
    impls=st.lists(
        st.tuples(st.frozensets(st.sampled_from("abcdefgh"), max_size=5),
                  st.integers(1, 5)),
        min_size=1, max_size=6),
)
def test_selection_invariants(hw, impls):
    """Invariants: (1) chosen impl's flags ⊆ hw; (2) no valid candidate has a
    strictly higher score; (3) among max-score candidates none is shorter."""
    defs = [_impl(flags=tuple(sorted(f)), body="\n".join(["return a"] * loc))
            for f, loc in impls]
    prim = _prim(defs)
    sel = choose(prim, "t", "float32", hw)
    cands = valid_candidates(prim, "t", "float32", hw)
    if not cands:
        assert sel is None
        return
    assert frozenset(sel.impl.flags) <= hw
    best = max(score(c, hw) for c in cands)
    assert score(sel.impl, hw) == best
    assert sel.impl.loc == min(c.loc for c in cands if score(c, hw) == best)


def test_non_native_selection_warns():
    """Paper §3.2: non-native workaround => build-time warning (Fig 6)."""
    from repro.core.model import TargetDef

    tgt = TargetDef(
        name="t", vendor="v", flags=("xla",), ctypes=("float32",),
        default_ctype="float32", lanes=128, sublanes=8, mxu=(128, 128),
        vmem_bytes=1, hbm_bytes=1, peak_flops_bf16=1.0, hbm_bw=1.0,
        ici_bw=1.0, ici_links=1)
    corpus = CorpusIR.from_defs(
        targets={"t": tgt},
        primitives={"p": _prim([_impl(flags=("xla",), native=False)])})
    ctx = GenerationResult(config=GenConfig(target="t"), corpus=corpus)
    SelectGPO().run(ctx)
    assert any("non-native workaround" in w for w in ctx.warnings)


def test_cherry_pick_closes_over_test_deps(lib_cpu):
    """Paper §1 'slim library': only= subset + transitive test requirements."""
    from repro.core import load_library

    lib = load_library("cpu_xla", only=("range_count",))
    prims = set(lib.PRIMITIVES)
    assert "range_count" in prims
    # range_count's test requires between_inclusive, hadd, select, set1, load
    assert {"between_inclusive", "hadd", "select", "set1", "load"} <= prims
    # but unrelated primitives are absent (slim)
    assert "flash_attention" not in prims
    assert "wkv6_scan" not in prims
