"""Shared helpers for Pallas kernels: padding/tiling arithmetic.

TPU tiling invariants (DESIGN.md §2, changed assumption 2): last dim in
multiples of 128 lanes, second-to-last in multiples of 8 sublanes (f32) /
16 (bf16); MXU likes 128x128 operands. Kernels pad to these and slice back.
"""

from __future__ import annotations

import jax.numpy as jnp

LANES = 128
SUBLANES = 8


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(x: int, m: int) -> int:
    return cdiv(x, m) * m


def pad_to(x, axis: int, multiple: int, value=0.0):
    """Pad ``axis`` of x up to a multiple; returns (padded, original_size)."""
    n = x.shape[axis]
    target = round_up(n, multiple)
    if target == n:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - n)
    return jnp.pad(x, widths, constant_values=value), n


def sublane_multiple(dtype) -> int:
    """Min second-to-last-dim tile for a dtype (8 for 32-bit, 16 for 16-bit, 32 for 8-bit)."""
    bits = jnp.dtype(dtype).itemsize * 8
    return {32: 8, 16: 16, 8: 32}.get(bits, 8)
