"""Public wrapper for the hadd kernel."""

from __future__ import annotations

from functools import partial

import jax

from ..common import pad_to, round_up, sublane_multiple
from . import kernel, ref


@partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def hadd(value, *, block_rows: int = 256, block_cols: int = 1024,
         interpret: bool = False):
    """Sum over the last axis of an arbitrary-rank input via the adder tree."""
    lead = value.shape[:-1]
    n = value.shape[-1]
    rows = 1
    for s in lead:
        rows *= s
    x2 = value.reshape(rows, n)
    # pad columns to a power of two >= 128 lanes
    p = 1 << max(7, (n - 1).bit_length())
    x2, _ = pad_to(x2, 1, p)
    bn = min(block_cols, p)
    sub = sublane_multiple(value.dtype)
    bm = min(block_rows, round_up(rows, sub))
    x2, _ = pad_to(x2, 0, bm)
    out = kernel.hadd_2d(x2, n_valid=n, block_rows=bm, block_cols=bn,
                         interpret=interpret)
    return out[:rows, 0].reshape(lead)


__all__ = ["hadd", "ref"]
