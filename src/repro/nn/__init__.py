"""Model zoo built on the generated TSL primitives (repro.tsl_api.ops).

Pure-functional style: params are pytrees of jnp arrays; every model family
exposes init / forward / prefill / decode_step through nn.model.build_model.
"""
