"""The four assigned input-shape cells (task brief) + applicability rules."""

from __future__ import annotations

from dataclasses import dataclass

from .arch import ArchConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ArchConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(runs?, reason). long_500k needs a sub-quadratic decode path
    (DESIGN.md §4); every arch here has a decoder, so decode cells all run."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md §4)"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    from .registry import ARCH_IDS

    return [(a, s) for a in ARCH_IDS for s in SHAPES]
