"""Pure-jnp oracle for RMSNorm (and the cpu_xla TSL implementation)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x, weight, *, eps: float = 1e-6):
    """RMS-normalize the last axis and scale: x / rms(x) * weight.

    Statistics in f32 regardless of input dtype (production LM convention).
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(ms + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)
