"""Documentation-generation GPO (paper §4.2 names doc generation as a prime
extension candidate — implemented here as a beyond-paper feature)."""

from __future__ import annotations

import collections

from .model import GenerationResult, GeneratedFile


class DocGenGPO:
    name = "docgen"

    def run(self, ctx: GenerationResult) -> GenerationResult:
        if ctx.errors:
            return ctx
        groups = collections.defaultdict(list)
        for name in sorted(ctx.selection):
            groups[ctx.primitives[name].group].append(name)
        for group, names in sorted(groups.items()):
            lines = [f"# TSL primitives — group `{group}` (target `{ctx.config.target}`)", ""]
            for name in names:
                prim = ctx.primitives[name]
                sels = ctx.selection[name]
                lines.append(f"## `{name}({prim.signature()})`")
                lines.append("")
                if prim.brief:
                    lines.append(prim.brief)
                    lines.append("")
                lines.append("| ctype | required flags | native | score | LOC | candidates |")
                lines.append("|---|---|---|---|---|---|")
                for ctype, sel in sorted(sels.items()):
                    lines.append(
                        f"| {ctype} | {', '.join(sel.impl.flags) or '—'} | "
                        f"{sel.impl.is_native} | {sel.score} | {sel.impl.loc} | "
                        f"{sel.candidates} |"
                    )
                lines.append("")
            ctx.files.append(GeneratedFile(
                relpath=f"docs/{group}.md", content="\n".join(lines), kind="doc"))
        return ctx
