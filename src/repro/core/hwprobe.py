"""Hardware probe (paper Fig 7a: ``cpuinfo.get_cpu_info()['flags']`` feeding
``--targets``). Here: query the live JAX backend and map it to an SRU name.
The generator can also be "tricked into assuming specific hardware"
(paper §4.1) by passing explicit flags — that is exactly how we generate the
TPU library on this CPU-only container.

``auto`` resolves into the UPD-defined SRU family (tsl_data/targets/):
cpu_xla on CPU (and GPU, conservatively) hosts, tpu_v5e on v5-class TPUs,
pallas_tpu on other TPUs. Flag sets are NOT duplicated here — the probed SRU's
own ``lscpu_flags`` from the UPD are the single source of truth."""

from __future__ import annotations

import jax


def live_target() -> str:
    backend = jax.default_backend()
    if backend == "tpu":
        kind = jax.devices()[0].device_kind.lower()
        return "tpu_v5e" if "v5" in kind else "pallas_tpu"
    # cpu, and conservatively gpu: the portable XLA dialect
    return "cpu_xla"


def live_flags() -> tuple[str, ...]:
    """Feature flags of the probed SRU, read from its UPD target document."""
    from . import loader

    name = live_target()
    for doc in loader.load_raw_targets():
        if doc.get("name") == name:
            return tuple(sorted(doc.get("lscpu_flags", ())))
    return ("xla",)
