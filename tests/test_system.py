"""End-to-end behaviour tests for the paper's system (TSLGen -> TSL -> apps).

These mirror the paper's own evaluation narrative (§5): the range-count
application written against the GENERATED library must agree with the
hand-written implementation (applicability), and regeneration must be
cache-stable (build-environment integration, Fig 7).
"""

import numpy as np
import jax.numpy as jnp


def _handwritten_range_count(data, lo, hi):
    """The 'Google Highway side' of Fig 8: hand-written jnp, no TSL."""
    m = jnp.logical_and(data >= lo, data <= hi)
    return jnp.sum(m.astype(jnp.int32))


def _tsl_range_count_composed(ops, data, lo, hi):
    """Fig 8b: the same algorithm COMPOSED from TSL primitives."""
    lv = ops.set1(lo, data.shape, dtype=str(data.dtype))
    uv = ops.set1(hi, data.shape, dtype=str(data.dtype))
    cv = ops.between_inclusive(data, lv, uv)
    iv = ops.select(cv, ops.set1(1, data.shape, dtype="int32"),
                    ops.set1(0, data.shape, dtype="int32"))
    return ops.hadd(iv.reshape(-1))


def test_applicability_composed_equals_handwritten(lib_cpu):
    rng = np.random.default_rng(0)
    data = jnp.asarray(rng.uniform(0, 100000, 1 << 14), jnp.float32)
    a = int(_tsl_range_count_composed(lib_cpu.ops, data, 5.0, 15.0))
    b = int(_handwritten_range_count(data, 5.0, 15.0))
    c = int(lib_cpu.ops.range_count(data, 5.0, 15.0))      # fused primitive
    d = int(lib_cpu.ops.range_count_popcnt(data, 5.0, 15.0))
    assert a == b == c == d


def test_same_app_runs_on_both_targets(lib_cpu, lib_interp):
    """Portability: identical application code, two generated libraries (the
    second routes through Pallas interpret kernels)."""
    rng = np.random.default_rng(1)
    data = jnp.asarray(rng.uniform(0, 100, 4096), jnp.float32)
    counts = {lib.TARGET_NAME: int(lib.ops.range_count(data, 5.0, 15.0))
              for lib in (lib_cpu, lib_interp)}
    assert len(set(counts.values())) == 1, counts


def test_regeneration_is_cache_stable():
    from repro.core import GenConfig, generate_library

    cfg = GenConfig(target="cpu_xla")
    dir1, ctx1 = generate_library(cfg)
    dir2, ctx2 = generate_library(cfg)
    assert dir1 == dir2
    assert ctx2 is None                     # disk-cache hit, no re-run


def test_cost_metadata_channel(lib_cpu):
    """Beyond-paper extension: cost formulas from the UPD are queryable."""
    assert lib_cpu.cost("matmul", "flops", M=8, N=8, K=8) == 2 * 8 * 8 * 8
    assert lib_cpu.cost("range_count", "flops", N=100) == 300


def test_target_info_exposed(lib_interp):
    """SRU data reachable from the generated library (Fig 4 analogue)."""
    t = lib_interp.TARGET
    assert t.lanes == 128 and t.sublanes == 8
    assert t.has("tpu", "mxu")
    assert t.vector_element_count("float32") == 1024
    assert t.vector_element_count("int8") == 4096
