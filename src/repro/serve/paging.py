"""Paged slot memory: block-table KV/state pools, copy-on-write prefix
sharing, and opt-in int8 pages.

The contiguous slot table reserves a max-bucket-sized cache per lane, so
residency is bounded by worst-case length. This layer turns the cache into a
POOL of fixed-size pages (one row per cache token, flat
``(n_pages * page_size, *row)`` arrays per paged state leaf) plus per-request
page lists (:class:`repro.serve.slots.SlotPages`), so HBM is charged for the
tokens a request has actually produced — "admit on pages available now, not
worst case". The engine keeps its jitted decode working set in the lanes
(bit-identical math — which is what makes paged vs. contiguous decode
token-for-token provable); this store is the RESIDENCY layer under it:

* prefill completion scatters the donor's paged leaves into its pages
  (``cache_page_write``) — fixed-size recurrent/cross "tail" leaves (the
  ``state_page_axes`` ``None`` entries) are snapshotted whole;
* a completed request with no free lane PARKS (it stays resident in pages,
  counted by ``resident_requests``) and ACTIVATES later by gathering its
  pages back into a donor (``cache_page_read``) and grafting it into a lane
  — this is what lets residency exceed the lane count;
* identical prompt prefixes are prefilled ONCE: full pages of the prefix are
  content-addressed in the :class:`PrefixStore` (keyed the way
  ``core/cache.py`` keys artifacts: a sha256 digest over everything that
  determines page content) and shared read-only with refcounts. Writes into
  a shared page go through copy-on-write (:meth:`PagedKVStore._cow`), so a
  sharer can never mutate another request's prefix;
* ``int8=True`` stores pages in the absmax-int8 wire format from
  ``repro.dist.compression`` (per-row scale alongside an int8 pool) —
  activation dequantizes on gather. Opt-in because it changes numerics.

FUSED mode (``PagedConfig.fused``, the default when the family has paged
leaves) removes the activation gather from the hot path entirely: pools keep
the leaf's own layout with the token axis split in-place into
``(n_pages, page)`` (e.g. a ``(L, 1, KH, S, hd)`` KV leaf becomes a
``(L, 1, KH, n_pages, page, hd)`` pool), so each layer's slice is directly
the ``(KH, n_pages, page, hd)`` operand of the ``attention_decode_paged`` /
``attention_verify_paged`` UPD primitives. KV-family slots then decode and
verify straight off the pool through per-step block tables (a dedicated
SCRATCH page absorbs table entries beyond a slot's coverage); lane
activation survives only for recurrent tails and as an explicit fallback,
and int8 pages dequantize per touched page inside the kernel instead of at
park/activate boundaries.

HOST SPILL adds an LRU tier under the pool: when the allocator runs dry and
no prefix entry is evictable, cold pages — unpinned (parked) requests'
exclusive, unshared data pages — are copied to host arrays and their device
pages released; they rehydrate into fresh pages when the request is touched
(pinned/activated) again. ``pages_free`` counts spillable pages as
reclaimable, so admission defers less under a cold-heavy pool, and the
spill/rehydrate counters land in ``report["paged"]``.

Page size is UPD data: the ``serve:`` block on ``cache_page_read`` declares
the candidates, bench selection picks the winner per hardware key, and
:func:`selected_page_size` probes the generated library for the choice (the
winning definition's page size IS the shape it returns). Gather/scatter run
through the generated primitives whenever the pool granularity matches the
selected definition, and through the same ``repro.kernels.paged`` bodies
directly when a caller overrides the page size.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.dist.compression import (dequantize_absmax_int8,
                                    quantize_absmax_int8)
from repro.kernels.paged import ref as _pref

from .slots import PageAllocator, PagesExhausted, SlotPages

DEFAULT_PAGE_SIZE = 64


def upd_page_defaults() -> dict:
    """The ``serve:`` block declared on the cache_page_read primitive:
    {"page_size": int, "page_sizes": [int, ...]}. Falls back to module
    defaults if the corpus (or the block) is missing."""
    try:
        from repro.core import load_corpus

        blk = dict(load_corpus().primitives["cache_page_read"].extra["serve"])
        return {"page_size": int(blk["page_size"]),
                "page_sizes": tuple(int(p) for p in blk["page_sizes"])}
    except Exception:
        return {"page_size": DEFAULT_PAGE_SIZE,
                "page_sizes": (DEFAULT_PAGE_SIZE,)}


def selected_page_size() -> int:
    """Page size of the generated library's SELECTED cache_page_read
    definition (bench winner per hardware key, or the flag heuristic's
    first candidate). Probed, not parsed: the definition's page size is
    exactly the number of rows it gathers per table entry, so the library
    itself is the source of truth."""
    try:
        from repro.tsl_api import ops

        out = ops.cache_page_read(jnp.zeros((1024, 1), jnp.float32),
                                  jnp.zeros((1,), jnp.int32))
        return int(out.shape[0])
    except Exception:
        return upd_page_defaults()["page_size"]


def prefix_key(*, arch: str, page_size: int, int8: bool, seed: int,
               prefix_rows: int, tokens, embeds=None) -> str:
    """Content address of a shareable prefix, CacheKey-style (core/cache.py):
    a sha256 digest over everything that determines the page content — the
    arch + param seed, the page geometry and precision, the media prefix,
    and the prefix token ids (plus the raw media bytes when present)."""
    h = hashlib.sha256()
    h.update(repr((arch, page_size, bool(int8), int(seed),
                   int(prefix_rows))).encode())
    h.update(np.ascontiguousarray(np.asarray(tokens, np.int64)).tobytes())
    if embeds is not None:
        h.update(np.ascontiguousarray(np.asarray(embeds,
                                                 np.float32)).tobytes())
    return h.hexdigest()[:16]


@dataclass(frozen=True)
class PagedConfig:
    """Engine-facing switch for paged slot memory.

    ``hbm_budget_bytes`` sizes the page pool (None: room for 2x the lane
    count at worst-case length — paged strictly dominates contiguous).
    ``page_size`` None probes the bench-selected definition.
    ``int8`` stores pages quantized (parked/shared requests reactivate
    through dequantization; active lanes always run full precision).
    ``max_inflight_prefills`` caps concurrent chunk schedules (None: 2x
    lanes).
    ``fused`` decodes/verifies KV-family slots directly against the block
    table via the ``attention_decode_paged``/``attention_verify_paged``
    primitives — no page->lane gather on the steady-state decode path.
    ``False`` forces the PR 8 activate-into-a-lane fallback (bit-identical
    to contiguous decode); families with no paged leaves (rwkv) fall back
    automatically either way."""

    hbm_budget_bytes: int | None = None
    page_size: int | None = None
    int8: bool = False
    prefix_sharing: bool = True
    max_inflight_prefills: int | None = None
    fused: bool = True


@dataclass
class PrefixEntry:
    pages: list[int]
    n_rows: int                       # cache rows the pages cover
    tail: dict | None                 # host snapshot of tail leaves at n_rows
    stamp: int                        # LRU tick


class PrefixStore:
    """Content-addressed store of shared, read-only prefix pages.

    ``publish`` retains the pages (the store holds one reference);
    ``lookup`` retains them again for the new sharer. Entries whose pages
    have no sharer left (refcount 1, held only by the store) are evictable
    LRU when the allocator runs dry."""

    def __init__(self, allocator: PageAllocator):
        self._alloc = allocator
        self.entries: dict[str, PrefixEntry] = {}
        self.hits = 0
        self.misses = 0
        self._tick = 0

    def lookup(self, key: str) -> PrefixEntry | None:
        entry = self.entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._tick += 1
        entry.stamp = self._tick
        for p in entry.pages:
            self._alloc.retain(p)
        self.hits += 1
        return entry

    def publish(self, key: str, pages: list[int], n_rows: int,
                tail: dict | None) -> bool:
        """Retain ``pages`` under ``key``; no-op if already present (the
        prefill-once guarantee: the engine publishes only on a miss)."""
        if key in self.entries:
            return False
        for p in pages:
            self._alloc.retain(p)
        self._tick += 1
        self.entries[key] = PrefixEntry(list(pages), n_rows, tail, self._tick)
        return True

    def evictable(self) -> list[str]:
        return [k for k, e in self.entries.items()
                if all(self._alloc.refcount(p) == 1 for p in e.pages)]

    def evictable_pages(self) -> int:
        return sum(len(self.entries[k].pages) for k in self.evictable())

    def evict_one(self) -> bool:
        """Drop the LRU entry with no active sharers. Returns False when
        nothing is evictable (every shared prefix is in live use)."""
        cands = self.evictable()
        if not cands:
            return False
        key = min(cands, key=lambda k: self.entries[k].stamp)
        for p in self.entries.pop(key).pages:
            self._alloc.release(p)
        return True


class PagedKVStore:
    """Device page pools + per-request page lists + the prefix store.

    Built from a DONOR's shape tree (slot axis of size 1) and the family's
    ``state_page_axes`` declaration. Leaves with a token axis get a flat
    row pool ``(n_pages * page_size, *row)`` (row = leaf shape with the
    token axis moved to the front and dropped); ``None`` leaves are TAIL
    state, stored as whole host snapshots per request and charged to the
    same page budget as a ceil(tail_bytes / page_bytes) reservation, so
    ``hbm_bytes_resident`` accounts every resident request uniformly —
    including pure-recurrent rwkv, whose "page" is its tail."""

    def __init__(self, donor_shapes: dict, page_axes: dict, *,
                 page_size: int, hbm_budget_bytes: int | None = None,
                 n_pages: int | None = None, int8: bool = False,
                 fused: bool = False):
        if not isinstance(donor_shapes, dict) or not isinstance(page_axes,
                                                                dict):
            raise TypeError("paged serving requires dict-shaped states "
                            "(all four decode families use flat dicts)")
        self.page = int(page_size)
        self.int8 = bool(int8)
        # leaf metadata from the donor shape tree
        self.paged: dict[str, tuple[int, tuple, object]] = {}
        self.tail_leaves: dict[str, tuple[tuple, object]] = {}
        tail_bytes = 0
        row_bytes = 0
        fp_row_bytes = 0
        for name, sd in donor_shapes.items():
            ax = page_axes.get(name)
            if ax is None:
                self.tail_leaves[name] = (tuple(sd.shape), sd.dtype)
                tail_bytes += int(np.prod(sd.shape)) * sd.dtype.itemsize
                continue
            row_shape = tuple(np.delete(np.asarray(sd.shape, int), ax))
            self.paged[name] = (int(ax), row_shape, sd.dtype)
            n_elem = int(np.prod(row_shape))
            fp_row_bytes += n_elem * sd.dtype.itemsize
            if self.int8:
                # int8 payload + one f32 scale per last-axis row
                row_bytes += n_elem + 4 * int(np.prod(row_shape[:-1]))
            else:
                row_bytes += n_elem * sd.dtype.itemsize
        self.row_bytes = row_bytes
        self.fp_row_bytes = fp_row_bytes
        self.tail_bytes = tail_bytes
        self.page_bytes = self.page * row_bytes if row_bytes \
            else max(tail_bytes, 1)
        if n_pages is None:
            if hbm_budget_bytes is None:
                raise ValueError("pass hbm_budget_bytes or n_pages")
            n_pages = max(int(hbm_budget_bytes) // self.page_bytes, 1)
        self.n_pages = int(n_pages)
        self.allocator = PageAllocator(self.n_pages)
        self.prefix_store = PrefixStore(self.allocator)
        # tail reservation: pages charged per request for its tail bytes
        self.tail_pages = -(-tail_bytes // self.page_bytes) if tail_bytes \
            else 0
        self.fused = bool(fused) and bool(self.paged)
        cap = self.n_pages * self.page
        self.pools: dict[str, jnp.ndarray] = {}
        self.scale_pools: dict[str, jnp.ndarray] = {}
        for name, (ax, row_shape, dt) in self.paged.items():
            if self.fused:
                # keep the leaf's own layout, token axis split in-place into
                # (n_pages, page): directly the fused primitives' pool operand
                shape = row_shape[:ax] + (self.n_pages, self.page) \
                    + row_shape[ax:]
                sshape = shape[:-1] + (1,)
            else:
                shape = (cap,) + row_shape
                sshape = (cap,) + row_shape[:-1] + (1,)
            if self.int8:
                self.pools[name] = jnp.zeros(shape, jnp.int8)
                self.scale_pools[name] = jnp.ones(sshape, jnp.float32)
            else:
                self.pools[name] = jnp.zeros(shape, dt)
        self.requests: dict[str, SlotPages] = {}
        self.tails: dict[str, dict | None] = {}
        self._tail_res: dict[str, list[int]] = {}
        # route through the generated UPD primitives when the pool
        # granularity matches the library's selected definition
        self._ops_page: int | None = None
        self.resident_peak = 0
        self.pages_used_peak = 0
        self.cow_copies = 0
        # host-spill tier: rid -> page index -> {leaf: (page, *row) host rows}
        self._spilled: dict[str, dict[int, dict[str, np.ndarray]]] = {}
        self._pinned: set[str] = set()
        self._lru: dict[str, int] = {}          # unpin stamps (cold order)
        self._lru_tick = 0
        self.spills = 0
        self.rehydrates = 0
        # fused decode needs every table entry to be a VALID page id even
        # past a slot's coverage: one scratch page absorbs them (and the
        # row writes of inactive slots)
        self.scratch_page: int | None = None
        if self.fused:
            self.scratch_page = self.allocator.alloc()

    # -- gather/scatter through the UPD primitives ---------------------------

    def _use_ops(self) -> bool:
        if self._ops_page is None:
            self._ops_page = selected_page_size()
        return self._ops_page == self.page

    def _offsets(self, pages) -> jnp.ndarray:
        return jnp.asarray([p * self.page for p in pages], jnp.int32)

    def _gather(self, pool, off):
        if self._use_ops():
            from repro.tsl_api import ops
            return ops.cache_page_read(pool, off)
        return _pref.page_read(pool, off, page=self.page)

    def _scatter(self, pool, rows, off):
        if self._use_ops():
            from repro.tsl_api import ops
            return ops.cache_page_write(pool, rows, off)
        return _pref.page_write(pool, rows, off, page=self.page)

    def _pool_gather(self, pool, ax, pids):
        """(len(pids)*page, *row) rows for page ids ``pids``, either layout."""
        if self.fused:
            idx = (slice(None),) * ax + (jnp.asarray(pids, jnp.int32),)
            g = jnp.moveaxis(pool[idx], (ax, ax + 1), (0, 1))
            return g.reshape((len(pids) * self.page,) + g.shape[2:])
        return self._gather(pool, self._offsets(pids))

    def _pool_scatter(self, pool, ax, rows, pids):
        if self.fused:
            blocks = rows.astype(pool.dtype).reshape(
                (len(pids), self.page) + rows.shape[1:])
            idx = (slice(None),) * ax + (jnp.asarray(pids, jnp.int32),)
            return pool.at[idx].set(jnp.moveaxis(blocks, (0, 1), (ax, ax + 1)))
        return self._scatter(pool, rows, self._offsets(pids))

    # -- accounting (the admission/"budget" interface) -----------------------

    def pages_for_rows(self, rows: int) -> int:
        """Pages one request needs for ``rows`` committed cache rows,
        including its tail reservation — the price admission charges."""
        data = -(-int(rows) // self.page) if self.paged else 0
        return data + self.tail_pages

    def pages_free(self) -> int:
        """Pages allocatable RIGHT NOW: the free list, every prefix-store
        page no live request shares (evictable on demand), and every cold
        page the host-spill tier can reclaim — admission defers only when
        none of the three can cover the request."""
        return self.allocator.free_pages + self.prefix_store.evictable_pages() \
            + self.spillable_pages()

    def hbm_bytes_resident(self) -> int:
        used = self.allocator.used_pages - (1 if self.scratch_page is not None
                                            else 0)
        return used * self.page_bytes

    def resident_requests(self) -> int:
        return len(self.requests)

    def contiguous_bytes_per_slot(self, max_len: int) -> int:
        """What ONE contiguous slot reserves at the same precision the
        lanes run (full-precision rows x max_len + the tail), for the
        resident-requests comparison at equal budget."""
        return max_len * self.fp_row_bytes + self.tail_bytes

    def _note_usage(self):
        self.pages_used_peak = max(self.pages_used_peak,
                                   self.allocator.used_pages)
        self.resident_peak = max(self.resident_peak, len(self.requests))

    def _alloc_page(self) -> int:
        while True:
            try:
                page = self.allocator.alloc()
                self._note_usage()
                return page
            except PagesExhausted:
                if self.prefix_store.evict_one():
                    continue
                if self._spill_one():
                    continue
                raise

    # -- request lifecycle ---------------------------------------------------

    def attach(self, rid: str, *, prompt_rows: int,
               share_key: str | None = None) -> int:
        """Admit ``rid``: retain shared prefix pages on a prefix-store hit,
        allocate the remaining prompt pages and the tail reservation.
        Returns the number of shared cache rows (0 on miss / sharing off).
        Raises PagesExhausted with everything rolled back if the pool
        cannot cover the request right now."""
        if rid in self.requests:
            raise ValueError(f"request {rid!r} already attached")
        sp = SlotPages()
        tail = None
        shared_rows = 0
        if share_key is not None:
            entry = self.prefix_store.lookup(share_key)
            if entry is not None:
                sp.pages = list(entry.pages)
                sp.n_shared = len(entry.pages)
                shared_rows = entry.n_rows
                tail = entry.tail
        got_tail_res: list[int] = []
        try:
            if self.paged:
                while sp.covered_rows(self.page) < prompt_rows:
                    sp.pages.append(self._alloc_page())
            for _ in range(self.tail_pages):
                got_tail_res.append(self._alloc_page())
        except PagesExhausted:
            for p in sp.pages[sp.n_shared:]:
                self.allocator.release(p)
            for p in sp.pages[:sp.n_shared]:
                self.allocator.release(p)      # drop the lookup retains
            for p in got_tail_res:
                self.allocator.release(p)
            raise
        sp.fill = shared_rows
        self.requests[rid] = sp
        self.tails[rid] = tail
        self._tail_res[rid] = got_tail_res
        self._pinned.add(rid)      # fresh requests are hot until parked
        self._note_usage()
        return shared_rows

    def grow(self, rid: str, rows: int) -> None:
        """Extend ``rid``'s page coverage to ``rows`` committed cache rows
        (decode growth). Raises PagesExhausted — the engine preempts."""
        sp = self.requests[rid]
        if self.paged:
            while sp.covered_rows(self.page) < rows:
                sp.pages.append(self._alloc_page())
        sp.fill = max(sp.fill, int(rows))

    def free(self, rid: str) -> None:
        """Release every page reference ``rid`` holds (prefix-store copies
        of shared pages survive through the store's own reference). Spilled
        pages (-1 markers) hold no device reference — their host copies are
        simply dropped."""
        sp = self.requests.pop(rid)
        for p in sp.pages:
            if p >= 0:
                self.allocator.release(p)
        for p in self._tail_res.pop(rid, ()):
            self.allocator.release(p)
        self.tails.pop(rid, None)
        self._spilled.pop(rid, None)
        self._pinned.discard(rid)
        self._lru.pop(rid, None)

    # -- host-spill tier -----------------------------------------------------

    def pin(self, rid: str) -> None:
        """Mark ``rid`` hot (active in a lane or on the fused decode path):
        its pages cannot spill, and any already-spilled pages rehydrate
        immediately."""
        self._pinned.add(rid)
        self._lru.pop(rid, None)
        self._rehydrate(rid)

    def unpin(self, rid: str) -> None:
        """Mark ``rid`` cold (parked): its exclusive data pages become
        spill candidates, coldest-parked first."""
        if rid not in self.requests:
            return
        self._pinned.discard(rid)
        self._lru_tick += 1
        self._lru[rid] = self._lru_tick

    def _spill_candidates(self, rid: str) -> list[int]:
        sp = self.requests[rid]
        return [i for i in range(sp.n_shared, len(sp.pages))
                if sp.pages[i] >= 0
                and self.allocator.refcount(sp.pages[i]) == 1]

    def spillable_pages(self) -> int:
        """Device pages the spill tier could reclaim right now: unpinned
        requests' exclusive (refcount-1, unshared) data pages."""
        return sum(len(self._spill_candidates(rid))
                   for rid in self.requests if rid not in self._pinned)

    def spilled_pages(self) -> int:
        return sum(len(d) for d in self._spilled.values())

    def host_spill_bytes(self) -> int:
        return self.spilled_pages() * self.page_bytes

    def _spill_one(self) -> bool:
        """Copy the coldest unpinned request's last exclusive data page to
        host arrays and release its device page. Returns False when nothing
        is spillable."""
        cold = sorted((rid for rid in self.requests
                       if rid not in self._pinned and
                       self._spill_candidates(rid)),
                      key=lambda r: self._lru.get(r, 0))
        if not cold:
            return False
        rid = cold[0]
        sp = self.requests[rid]
        i = self._spill_candidates(rid)[-1]
        pid = sp.pages[i]
        host: dict[str, np.ndarray] = {}
        for name in self.pools:
            ax = self.paged[name][0]
            host[name] = np.asarray(self._pool_gather(self.pools[name], ax,
                                                      [pid]))
            if self.int8:
                host[f"{name}__scale"] = np.asarray(
                    self._pool_gather(self.scale_pools[name], ax, [pid]))
        self._spilled.setdefault(rid, {})[i] = host
        sp.pages[i] = -1
        self.allocator.release(pid)
        self.spills += 1
        return True

    def _rehydrate(self, rid: str) -> None:
        """Restore every spilled page of ``rid`` into fresh device pages
        (touch-on-activate). The request is pinned for the duration so the
        allocation fallback cannot spill it back out from under itself."""
        spilled = self._spilled.get(rid)
        if not spilled:
            return
        was_pinned = rid in self._pinned
        self._pinned.add(rid)
        try:
            sp = self.requests[rid]
            for i in sorted(spilled):
                host = spilled[i]
                fresh = self._alloc_page()
                for name in self.pools:
                    ax = self.paged[name][0]
                    self.pools[name] = self._pool_scatter(
                        self.pools[name], ax, jnp.asarray(host[name]),
                        [fresh])
                    if self.int8:
                        self.scale_pools[name] = self._pool_scatter(
                            self.scale_pools[name], ax,
                            jnp.asarray(host[f"{name}__scale"]), [fresh])
                sp.pages[i] = fresh
                self.rehydrates += 1
            del self._spilled[rid]
        finally:
            if not was_pinned:
                self._pinned.discard(rid)

    # -- data movement -------------------------------------------------------

    def _cow(self, sp: SlotPages, p0: int, p1: int) -> None:
        """Copy-on-write: any page in [p0, p1) shared with someone else
        (refcount > 1) is copied into a fresh exclusive page before the
        caller writes. A sharer can therefore never mutate a page another
        request (or the prefix store) still reads."""
        for i in range(p0, min(p1, len(sp.pages))):
            pid = sp.pages[i]
            if self.allocator.refcount(pid) <= 1:
                continue
            fresh = self._alloc_page()
            for name in self.pools:
                ax = self.paged[name][0]
                rows = self._pool_gather(self.pools[name], ax, [pid])
                self.pools[name] = self._pool_scatter(self.pools[name], ax,
                                                      rows, [fresh])
                if self.int8:
                    srows = self._pool_gather(self.scale_pools[name], ax,
                                              [pid])
                    self.scale_pools[name] = self._pool_scatter(
                        self.scale_pools[name], ax, srows, [fresh])
            self.allocator.release(pid)
            sp.pages[i] = fresh
            sp.n_shared = min(sp.n_shared, i)
            self.cow_copies += 1

    def write_rows(self, rid: str, row0: int, row1: int,
                   rows_by_leaf: dict) -> None:
        """Write cache rows [row0, row1) for every paged leaf (rows_by_leaf:
        {leaf: (row1-row0, *row) arrays}) through copy-on-write + the
        cache_page_write primitive. row0 must be page-aligned; the final
        partial page is zero-padded (those rows are beyond the request's
        fill, never read)."""
        if not self.paged or row1 <= row0:
            return
        if row0 % self.page:
            raise ValueError(f"write start {row0} not page-aligned "
                             f"({self.page})")
        sp = self.requests[rid]
        p0, p1 = row0 // self.page, -(-row1 // self.page)
        if p1 > len(sp.pages):
            raise ValueError(f"write [{row0},{row1}) beyond {rid!r}'s "
                             f"{len(sp.pages)} pages")
        self._cow(sp, p0, p1)
        pids = sp.pages[p0:p1]
        need = (p1 - p0) * self.page
        for name in self.pools:
            ax = self.paged[name][0]
            rows = rows_by_leaf[name]
            if rows.shape[0] < need:
                pad = jnp.zeros((need - rows.shape[0],) + rows.shape[1:],
                                rows.dtype)
                rows = jnp.concatenate([rows, pad], axis=0)
            if self.int8:
                q, scale = quantize_absmax_int8(rows)
                self.pools[name] = self._pool_scatter(self.pools[name], ax,
                                                      q, pids)
                self.scale_pools[name] = self._pool_scatter(
                    self.scale_pools[name], ax, scale, pids)
            else:
                self.pools[name] = self._pool_scatter(self.pools[name], ax,
                                                      rows, pids)

    def snapshot_tail(self, donor: dict) -> dict:
        """Host copies of the tail leaves (donation-safe: the donor buffer
        may be donated to a jitted insert right after)."""
        return {name: np.asarray(donor[name]) for name in self.tail_leaves}

    def store_donor(self, rid: str, donor: dict, *, fill: int,
                    tail: dict | None = None) -> None:
        """Scatter a completed prefill's paged rows [shared_end, fill) into
        the request's pages and stash its tail snapshot. Shared prefix rows
        are already resident — exactly the prefill-once contract."""
        sp = self.requests[rid]
        self.grow(rid, fill)
        row0 = sp.n_shared * self.page
        if self.paged and fill > row0:
            slabs = {}
            for name, (ax, _, _) in self.paged.items():
                rows = jnp.moveaxis(donor[name], ax, 0)
                slabs[name] = rows[row0:min(fill, rows.shape[0])]
            self.write_rows(rid, row0, fill, slabs)
        sp.fill = int(fill)
        if tail is not None:
            self.tails[rid] = tail
        elif self.tail_leaves:
            self.tails[rid] = self.snapshot_tail(donor)

    def load_donor(self, rid: str, donor: dict) -> dict:
        """Gather the request's pages (and tail snapshot) back into a
        freshly zeroed donor — the parked-request activation path (and the
        fused engine's explicit lane fallback). Full precision pages
        round-trip bit-exactly; int8 pages dequantize. Donor templates
        without the paged leaves (a fused engine restoring tails only)
        skip the gather entirely."""
        self._rehydrate(rid)
        sp = self.requests[rid]
        out = dict(donor)
        want = [n for n in self.paged if n in out]
        if want and sp.pages and sp.fill:
            for name in want:
                ax, _, dt = self.paged[name]
                if self.int8:
                    q = self._pool_gather(self.pools[name], ax, sp.pages)
                    s = self._pool_gather(self.scale_pools[name], ax,
                                          sp.pages)
                    rows = dequantize_absmax_int8(q, s, dtype=dt)
                else:
                    rows = self._pool_gather(self.pools[name], ax, sp.pages)
                n_rows = out[name].shape[ax]
                rows = rows[:min(sp.fill, n_rows)]
                full = jnp.zeros((n_rows,) + rows.shape[1:], dt)
                full = full.at[:rows.shape[0]].set(rows)
                out[name] = jnp.moveaxis(full, 0, ax)
        tail = self.tails.get(rid)
        if tail:
            for name, arr in tail.items():
                _, dt = self.tail_leaves[name]
                out[name] = jnp.asarray(arr, dt)
        return out

    # -- fused-decode interface ----------------------------------------------

    def device_pools(self) -> dict:
        """The device-resident pool arrays, keyed by leaf name (int8 scale
        pools ride along as ``{leaf}__scale``) — the engine threads these
        through its donated jitted step calls."""
        out = dict(self.pools)
        out.update({f"{n}__scale": s for n, s in self.scale_pools.items()})
        return out

    def set_device_pools(self, pools: dict) -> None:
        """Adopt the pool arrays a jitted step returned (the donated,
        in-place-updated successors of :meth:`device_pools`)."""
        for n in self.pools:
            self.pools[n] = pools[n]
        for n in self.scale_pools:
            self.scale_pools[n] = pools[f"{n}__scale"]

    def table_row(self, rid: str, width: int) -> np.ndarray:
        """``rid``'s block-table row, padded to ``width`` entries with the
        scratch page (every entry must be a valid page id — the fused
        kernels' index maps fetch unconditionally). The request must be
        pinned/rehydrated: spilled pages have no device identity."""
        sp = self.requests[rid]
        pages = sp.pages[:width]
        if any(p < 0 for p in pages):
            raise ValueError(f"request {rid!r} has spilled pages — pin() "
                             "before building its table row")
        row = np.full((width,), self.scratch_page, np.int32)
        row[:len(pages)] = pages
        return row

    def publish_prefix(self, rid: str, key: str, *, n_rows: int,
                       tail: dict | None) -> bool:
        """Publish ``rid``'s leading full pages covering [0, n_rows) under
        ``key``. No-op when the key is already present."""
        sp = self.requests[rid]
        if self.paged:
            if n_rows % self.page:
                raise ValueError(f"publish boundary {n_rows} not "
                                 f"page-aligned ({self.page})")
            pages = sp.pages[:n_rows // self.page]
        else:
            pages = []
        return self.prefix_store.publish(key, pages, n_rows, tail)
