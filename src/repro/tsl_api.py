"""Framework-facing TSL access point.

Every higher layer (nn/, train/, serve/) calls vector primitives ONLY through
this module, so switching execution dialect = regenerating the library
(``REPRO_TSL_TARGET=pallas_interpret`` etc.) — the paper's portability claim,
upheld structurally.

``load_library`` is backed by the content-addressed artifact cache: with an
unchanged UPD fingerprint + probed hardware flags the warm path imports the
cached package without re-running a single GPO. ``warmup()`` pre-generates
several targets off one validated corpus (zero re-validation per target).
"""

from __future__ import annotations

import os
from pathlib import Path
from types import ModuleType

from repro.core import generate_all, load_library

_lib: ModuleType | None = None


def lib(force: bool = False) -> ModuleType:
    global _lib
    if _lib is None or force:
        _lib = load_library(os.environ.get("REPRO_TSL_TARGET", "auto"))
    return _lib


def warmup(targets: tuple[str, ...] | None = None) -> dict[str, Path]:
    """Populate the artifact cache for ``targets`` (default: every corpus
    target) so later ``load_library`` calls are pure cache hits."""
    return generate_all(targets)


class _OpsProxy:
    """Late-bound proxy so `from repro.tsl_api import ops` works before the
    library is generated (first attribute access triggers generation)."""

    def __getattr__(self, name: str):
        return getattr(lib().ops, name)


ops = _OpsProxy()


def target_name() -> str:
    return lib().TARGET_NAME


def cost(primitive: str, term: str, **shapes) -> float:
    return lib().cost(primitive, term, **shapes)
