"""Whisper-style encoder-decoder backbone (conv frontend is a STUB: the
encoder consumes precomputed frame embeddings from input_specs).

Encoder: bidirectional self-attn + GELU MLP (LayerNorm).
Decoder: causal self-attn (KV cache) + cross-attn against encoder output
(cross K/V computed once) + GELU MLP.
Positions: sinusoidal added to encoder input; RoPE in decoder self-attention
(documented deviation from Whisper's learned positions — DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.tsl_api import ops as tsl

from repro.nn import flags as _nn_flags


def _scan(f, init, xs, **kw):
    return jax.lax.scan(f, init, xs, unroll=_nn_flags.scan_unroll(), **kw)


from .attention import (attention_decode, attention_forward,
                        attention_prefill_chunk, attention_span_paged,
                        attention_verify, cross_attention_forward,
                        init_attention, project_kv)
from .common import apply_norm_params, dense_init, embed_init, init_norm, split_keys
from .mlp import init_mlp, mlp_forward


def _sinusoid(s: int, d: int):
    # Computed on the HOST (numpy) so the table enters the graph as a literal
    # constant. A traced formulation (iota -> sin/cos -> concatenate) is
    # miscompiled by XLA CPU's SPMD partitioner when the result feeds any
    # sharded computation — the partitioned concat-of-iotas reassembles with
    # the halves misplaced, silently corrupting every encoder activation
    # (observed under --xla_force_host_platform_device_count; the constant
    # costs nothing and is immune).
    pos = np.arange(s, dtype=np.float32)[:, None]
    dim = np.arange(d // 2, dtype=np.float32)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=-1))


def _init_enc_block(key, cfg, dtype):
    ks = split_keys(key, 2)
    return {
        "attn_norm": init_norm(cfg, dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "mlp_norm": init_norm(cfg, dtype),
        "mlp": init_mlp(ks[1], cfg, dtype),
    }


def _init_dec_block(key, cfg, dtype):
    ks = split_keys(key, 3)
    return {
        "self_norm": init_norm(cfg, dtype),
        "self_attn": init_attention(ks[0], cfg, dtype),
        "cross_norm": init_norm(cfg, dtype),
        "cross_attn": init_attention(ks[1], cfg, dtype),
        "mlp_norm": init_norm(cfg, dtype),
        "mlp": init_mlp(ks[2], cfg, dtype),
    }


def init_encdec(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ks = split_keys(key, 5)
    ekeys = jnp.stack(split_keys(ks[0], cfg.n_enc_layers))
    dkeys = jnp.stack(split_keys(ks[1], cfg.n_layers))
    return {
        "embed": embed_init(ks[2], (cfg.padded_vocab, cfg.d_model), dtype),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(ekeys),
        "enc_norm": init_norm(cfg, dtype),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dkeys),
        "final_norm": init_norm(cfg, dtype),
        "head": dense_init(ks[3], (cfg.d_model, cfg.padded_vocab), dtype),
    }


def encode(params, audio_embeds, cfg, *, remat: bool = True):
    """audio_embeds (B,S,D) -> encoder output (B,S,D)."""
    s, d = audio_embeds.shape[1], audio_embeds.shape[2]
    x = audio_embeds + _sinusoid(s, d).astype(audio_embeds.dtype)
    positions = jnp.arange(s)

    from repro.dist.sharding import logical_constraint

    def body(x, bp):
        h, _ = attention_forward(bp["attn"], apply_norm_params(cfg, bp["attn_norm"], x),
                                 cfg, causal=False, positions=positions)
        x = x + h
        x = x + mlp_forward(bp["mlp"], apply_norm_params(cfg, bp["mlp_norm"], x), cfg)
        return logical_constraint(x, "batch", None, None), None

    b = jax.checkpoint(body, prevent_cse=False) if remat else body
    x, _ = _scan(b, x, params["enc_blocks"])
    return apply_norm_params(cfg, params["enc_norm"], x)


def encdec_forward(params, tokens, cfg, *, audio_embeds, remat: bool = True,
                   collect_cache: bool = False, last_only: bool = False):
    """Teacher-forced decode over full token sequence."""
    enc = encode(params, audio_embeds, cfg, remat=remat)
    x = tsl.embed_lookup(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])

    from repro.dist.sharding import logical_constraint

    def body(x, bp):
        h, kv = attention_forward(bp["self_attn"],
                                  apply_norm_params(cfg, bp["self_norm"], x),
                                  cfg, causal=True, positions=positions)
        x = x + h
        ck, cv = project_kv(bp["cross_attn"], enc, cfg)
        x = x + cross_attention_forward(
            bp["cross_attn"], apply_norm_params(cfg, bp["cross_norm"], x), ck, cv, cfg)
        x = x + mlp_forward(bp["mlp"], apply_norm_params(cfg, bp["mlp_norm"], x), cfg)
        return logical_constraint(x, "batch", None, None), (kv if collect_cache else None)

    b = jax.checkpoint(body, prevent_cse=False) if remat else body
    x, kvs = _scan(b, x, params["dec_blocks"])
    if last_only:
        x = x[:, -1:]
    x = apply_norm_params(cfg, params["final_norm"], x)
    logits = tsl.matmul(x, params["head"])
    return logits, jnp.float32(0), (kvs, enc) if collect_cache else None


def init_encdec_state(cfg, batch: int, max_len: int, enc_len: int, dtype):
    kh, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((cfg.n_layers, batch, kh, max_len, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, kh, max_len, hd), dtype),
        # cross K/V precomputed from the encoder at prefill time
        "cross_k": jnp.zeros((cfg.n_layers, batch, kh, enc_len, hd), dtype),
        "cross_v": jnp.zeros((cfg.n_layers, batch, kh, enc_len, hd), dtype),
    }


def state_batch_axes(state):
    """Slot-axis position per state leaf (serve-layer state surgery): self-
    attn caches AND the per-request cross K/V are (L, B, KH, S, hd) — the
    request axis sits at 1. NOTE: cross K/V leaves are sized by the encoder
    length, so a donor only fits a batched state built with the SAME
    enc_len (the engine validates this before inserting)."""
    return {k: 1 for k in state}


def state_page_axes(state):
    """Token-axis per leaf for PAGED serving: decoder self-attention caches
    grow one row per emitted token (axis 3) and page; the cross K/V leaves
    are computed ONCE from the encoder at prefill and never grow — they are
    per-request TAIL state (``None``), sized by enc_len, snapshotted whole
    (and shared with the prefix store when prompts coincide)."""
    return {k: 3 if k in ("k", "v") else None for k in state}


def encdec_prefill(params, tokens, cfg, *, audio_embeds, max_len: int):
    enc = encode(params, audio_embeds, cfg, remat=False)

    def cross_kv(bp):
        return project_kv(bp["cross_attn"], enc, cfg)

    ck, cv = jax.lax.map(cross_kv, params["dec_blocks"])
    logits, _, cache = encdec_forward(params, tokens, cfg,
                                      audio_embeds=audio_embeds, remat=False,
                                      collect_cache=True, last_only=True)
    (k, v), _ = cache
    pad = max_len - k.shape[3]
    if pad > 0:
        widths = ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))
        k, v = jnp.pad(k, widths), jnp.pad(v, widths)
    return logits[:, -1], {"k": k, "v": v, "cross_k": ck, "cross_v": cv}


def encdec_prefill_chunk(params, state, tokens, pos, cfg, *, audio_embeds=None):
    """Continuation prefill of one decoder chunk. On the FIRST chunk
    (``audio_embeds`` given) the encoder runs once and the per-request cross
    K/V are seeded into the state; later chunks reuse them. Self-attention
    writes the chunk's K/V at rows [pos, pos+C). Returns (logits (B,C,V),
    new state)."""
    if audio_embeds is not None:
        enc = encode(params, audio_embeds, cfg, remat=False)

        def cross_kv(bp):
            return project_kv(bp["cross_attn"], enc, cfg)

        ck, cv = jax.lax.map(cross_kv, params["dec_blocks"])
        state = {**state, "cross_k": ck.astype(state["cross_k"].dtype),
                 "cross_v": cv.astype(state["cross_v"].dtype)}
    x = tsl.embed_lookup(params["embed"], tokens)

    def body(x_c, inp):
        bp, kc, vc, ck, cv = inp
        h, kc, vc = attention_prefill_chunk(
            bp["self_attn"], apply_norm_params(cfg, bp["self_norm"], x_c),
            kc, vc, pos, cfg)
        x_c = x_c + h
        q_in = apply_norm_params(cfg, bp["cross_norm"], x_c)
        x_c = x_c + cross_attention_forward(bp["cross_attn"], q_in, ck, cv, cfg)
        x_c = x_c + mlp_forward(bp["mlp"], apply_norm_params(cfg, bp["mlp_norm"], x_c), cfg)
        return x_c, (kc, vc)

    x, (k, v) = _scan(
        body, x, (params["dec_blocks"], state["k"], state["v"],
                  state["cross_k"], state["cross_v"]))
    x = apply_norm_params(cfg, params["final_norm"], x)
    logits = tsl.matmul(x, params["head"])
    return logits, {**state, "k": k, "v": v}


def encdec_verify_step(params, state, tokens, pos, cfg):
    """Speculative-decoding verify span: causal self-attention over the span
    through the attention_verify primitive (K/V slab at [pos, pos+SV));
    cross-attention is non-causal row-by-row against the precomputed cross
    K/V, so any span width scores exactly. KV rollback is free (kv_len
    truncation) — the updated state is returned, rejected rows sit beyond
    the committed fill. Returns (logits (B,SV,V), new state)."""
    x = tsl.embed_lookup(params["embed"], tokens)

    def body(x_c, inp):
        bp, kc, vc, ck, cv = inp
        h, kc, vc = attention_verify(
            bp["self_attn"], apply_norm_params(cfg, bp["self_norm"], x_c),
            kc, vc, pos, cfg)
        x_c = x_c + h
        q_in = apply_norm_params(cfg, bp["cross_norm"], x_c)
        x_c = x_c + cross_attention_forward(bp["cross_attn"], q_in, ck, cv, cfg)
        x_c = x_c + mlp_forward(bp["mlp"], apply_norm_params(cfg, bp["mlp_norm"], x_c), cfg)
        return x_c, (kc, vc)

    x, (k, v) = _scan(
        body, x, (params["dec_blocks"], state["k"], state["v"],
                  state["cross_k"], state["cross_v"]))
    x = apply_norm_params(cfg, params["final_norm"], x)
    logits = tsl.matmul(x, params["head"])
    return logits, {**state, "k": k, "v": v}


def _encdec_paged_span(params, state, pools, tables, tokens, pos, cfg,
                       span_op):
    """Fused-paged decode/verify body: decoder self-attention writes and
    reads its span straight against the page pools (attention_span_paged);
    cross-attention still runs against the per-request cross K/V TAILS in
    ``state`` (fixed-size, never paged). Returns (logits, pools)."""
    x = tsl.embed_lookup(params["embed"], tokens)
    int8 = "k__scale" in pools
    xs = [params["dec_blocks"], pools["k"], pools["v"],
          state["cross_k"], state["cross_v"]]
    if int8:
        xs += [pools["k__scale"], pools["v__scale"]]

    def body(x_c, inp):
        if int8:
            bp, kp, vp, ck, cv, ks, vs = inp
            ks, vs = ks[0], vs[0]
        else:
            bp, kp, vp, ck, cv = inp
            ks = vs = None
        h, kp0, vp0, ks0, vs0 = attention_span_paged(
            bp["self_attn"], apply_norm_params(cfg, bp["self_norm"], x_c),
            kp[0], vp[0], tables, pos, cfg, span_op,
            k_scale=ks, v_scale=vs)
        x_c = x_c + h
        q_in = apply_norm_params(cfg, bp["cross_norm"], x_c)
        x_c = x_c + cross_attention_forward(bp["cross_attn"], q_in, ck, cv, cfg)
        x_c = x_c + mlp_forward(bp["mlp"], apply_norm_params(cfg, bp["mlp_norm"], x_c), cfg)
        ys = (kp0[None], vp0[None])
        if int8:
            ys += (ks0[None], vs0[None])
        return x_c, ys

    x, ys = _scan(body, x, tuple(xs))
    pools = {**pools, "k": ys[0], "v": ys[1]}
    if int8:
        pools["k__scale"], pools["v__scale"] = ys[2], ys[3]
    x = apply_norm_params(cfg, params["final_norm"], x)
    return tsl.matmul(x, params["head"]), pools


def encdec_decode_step_paged(params, state, pools, tables, tokens_t, pos, cfg):
    """Fused paged decode for the decoder: self-attention straight off the
    page pools, cross-attention against the cross K/V tails. Returns
    (logits (B,V), state, pools)."""
    logits, pools = _encdec_paged_span(params, state, pools, tables,
                                       tokens_t, pos, cfg,
                                       tsl.attention_decode_paged)
    return logits[:, 0], state, pools


def encdec_verify_step_paged(params, state, pools, tables, tokens, pos, cfg):
    """Fused paged verify span (rollback free — rejected rows sit beyond
    the committed kv_len). Returns (logits (B,SV,V), state, pools)."""
    logits, pools = _encdec_paged_span(params, state, pools, tables,
                                       tokens, pos, cfg,
                                       tsl.attention_verify_paged)
    return logits, state, pools


def encdec_decode_step(params, state, tokens_t, pos, cfg):
    x = tsl.embed_lookup(params["embed"], tokens_t)

    def body(x_t, inp):
        bp, kc, vc, ck, cv = inp
        h, kc, vc = attention_decode(
            bp["self_attn"], apply_norm_params(cfg, bp["self_norm"], x_t),
            kc, vc, pos, cfg)
        x_t = x_t + h
        q_in = apply_norm_params(cfg, bp["cross_norm"], x_t)
        x_t = x_t + cross_attention_forward(bp["cross_attn"], q_in, ck, cv, cfg)
        x_t = x_t + mlp_forward(bp["mlp"], apply_norm_params(cfg, bp["mlp_norm"], x_t), cfg)
        return x_t, (kc, vc)

    x, (k, v) = _scan(
        body, x, (params["dec_blocks"], state["k"], state["v"],
                  state["cross_k"], state["cross_v"]))
    x = apply_norm_params(cfg, params["final_norm"], x)
    logits = tsl.matmul(x, params["head"])[:, 0]
    return logits, {**state, "k": k, "v": v}
