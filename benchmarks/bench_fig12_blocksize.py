"""Paper Fig 12: throughput of the range-count use case vs vector size
(paper: FPGA vector sizes 128..2048 bit, 512-bit saturates PCIe at ~12 GiB/s).

TPU adaptation (DESIGN.md §2): "vector size" becomes the Pallas BlockSpec row
count — the VMEM working-set knob. Two readouts per block size:
  * CPU wall-clock of the XLA path (real, this host);
  * the kernel's roofline-model throughput on v5e (bytes/HBM_bw — the kernel
    is purely memory-bound, so the model is exact up to VMEM pipelining).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.roofline import HBM_BW

from .common import emit, time_fn

N = 1 << 24          # 16M elements = 64 MiB
BLOCK_ROWS = [8, 32, 128, 512, 2048]


def run() -> list[str]:
    rng = np.random.default_rng(0)
    data = jnp.asarray(rng.uniform(0, 100, N), jnp.float32)
    out = []
    from repro.kernels.range_count import ops, ref

    t_ref = time_fn(jax.jit(lambda d: ref.range_count(d, 5.0, 15.0)), data,
                    n_iter=10)
    gib = N * 4 / 2**30
    emit("fig12_xla_cpu_reference", t_ref, f"{gib / (t_ref/1e6):.2f}GiB/s")
    for bm in BLOCK_ROWS:
        # v5e roofline: one HBM pass at 819 GB/s; VMEM tile = bm x 128 x 4B
        tile_kib = bm * 128 * 4 / 1024
        t_model = N * 4 / HBM_BW * 1e6
        eff = min(1.0, tile_kib / 512)   # tiles < 4 sublane-groups underfill the pipeline
        emit(f"fig12_v5e_model_block{bm}", t_model / eff,
             f"tile={tile_kib:.0f}KiB eff={eff:.2f} "
             f"{gib / (t_model / eff / 1e6):.0f}GiB/s")
        out.append(f"block {bm}: {gib / (t_model / eff / 1e6):.0f} GiB/s (model)")
    return out


if __name__ == "__main__":
    run()
