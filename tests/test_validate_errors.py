"""ValidateGPO error paths (ISSUE 6 satellite): malformed documents must
become collected, actionable errors — never exceptions mid-validation — and
the strict corpus build must refuse to produce an IR from a broken UPD."""

import pytest

from repro.core.corpus import CorpusPipeline
from repro.core.model import CorpusBuild
from repro.core.pipeline import GenerationError
from repro.core.validate import ValidateGPO


def run_validate(raw_targets=(), raw_primitives=()):
    cb = CorpusBuild()
    cb.raw_targets = [dict(d) for d in raw_targets]
    cb.raw_primitives = [dict(d) for d in raw_primitives]
    return ValidateGPO().run(cb)


GOOD_TARGET = {"name": "t0", "lscpu_flags": ["xla"], "ctypes": ["float32"]}
GOOD_PRIM = {
    "primitive_name": "p",
    "parameters": [{"name": "x"}],
    "definitions": [{"target_extension": "t0", "ctype": ["float32"],
                     "lscpu_flags": ["xla"], "implementation": "return x\n"}],
    "testing": [{"name": "t", "implementation": "pass"}],
}


def test_well_formed_docs_validate_clean():
    ctx = run_validate([GOOD_TARGET], [GOOD_PRIM])
    assert not ctx.errors
    assert set(ctx.targets) == {"t0"} and set(ctx.primitives) == {"p"}


def test_target_missing_mandatory_fields():
    ctx = run_validate([{"name": "t0"}])          # no lscpu_flags/ctypes
    assert any("lscpu_flags" in e and "mandatory" in e for e in ctx.errors)
    assert any("ctypes" in e and "mandatory" in e for e in ctx.errors)
    assert not ctx.targets                        # broken doc never registered


def test_target_with_wrong_field_types():
    bad = dict(GOOD_TARGET, lscpu_flags="xla", lanes="many")
    ctx = run_validate([bad])
    assert any("lscpu_flags" in e and "expected list[str]" in e
               for e in ctx.errors)
    assert any("lanes" in e and "expected int" in e for e in ctx.errors)


def test_duplicate_target_names():
    ctx = run_validate([GOOD_TARGET, GOOD_TARGET])
    assert any("duplicate target 't0'" in e for e in ctx.errors)


def test_duplicate_primitive_names():
    ctx = run_validate([GOOD_TARGET], [GOOD_PRIM, GOOD_PRIM])
    assert any("duplicate primitive 'p'" in e for e in ctx.errors)


def test_definition_references_unknown_target():
    prim = dict(GOOD_PRIM)
    prim["definitions"] = [dict(GOOD_PRIM["definitions"][0],
                                target_extension="nowhere")]
    ctx = run_validate([GOOD_TARGET], [prim])
    assert any("unknown target 'nowhere'" in e for e in ctx.errors)


def test_definition_target_extension_wrong_type():
    prim = dict(GOOD_PRIM)
    prim["definitions"] = [dict(GOOD_PRIM["definitions"][0],
                                target_extension=123)]
    ctx = run_validate([GOOD_TARGET], [prim])
    assert any("target_extension must be str or list[str]" in e
               for e in ctx.errors)


def test_unknown_ctype_warns_but_validates():
    prim = dict(GOOD_PRIM)
    prim["definitions"] = [dict(GOOD_PRIM["definitions"][0],
                                ctype=["float32", "int8"])]
    ctx = run_validate([GOOD_TARGET], [prim])
    assert not ctx.errors
    assert any("ctype 'int8' not listed for target 't0'" in w
               for w in ctx.warnings)


def test_primitive_missing_definitions_is_an_error():
    ctx = run_validate([GOOD_TARGET], [{"primitive_name": "p"}])
    assert any("definitions" in e and "mandatory" in e for e in ctx.errors)
    assert not ctx.primitives


def test_untested_primitive_warns_per_paper():
    prim = {k: v for k, v in GOOD_PRIM.items() if k != "testing"}
    ctx = run_validate([GOOD_TARGET], [prim])
    assert any("no test cases defined" in w for w in ctx.warnings)


def test_strict_corpus_build_refuses_malformed_target_yaml(tmp_path):
    (tmp_path / "targets").mkdir()
    (tmp_path / "primitives").mkdir()
    (tmp_path / "targets" / "broken.yaml").write_text(
        "---\nname: 3\nlanes: \"wide\"\n...\n")
    with pytest.raises(GenerationError) as ei:
        CorpusPipeline().build((str(tmp_path),))
    msg = str(ei.value)
    assert "mandatory entry missing" in msg
    assert "expected str" in msg or "expected int" in msg
