"""Fault-tolerant checkpointing: sharded-leaf save, atomic manifest commit,
async writer, restore-latest with ELASTIC remeshing.

Layout:  <dir>/step_<N>/
             manifest.json        (tree structure, shapes, dtypes, step,
                                   data-pipeline state, integrity checksums)
             leaf_<i>.npy         (one file per pytree leaf, host-gathered)

Atomicity: written into step_<N>.tmp, fsynced, renamed — a crash mid-write
never corrupts the latest checkpoint (restore scans for the highest committed
step). Async: device->host transfer happens on the caller thread (cheap),
file IO on a worker thread; `wait()` joins before the next save or exit.

Elasticity: leaves are saved UNSHARDED (host-gathered); restore device_puts
them under the *target* mesh's shardings, so a (16,16) checkpoint restores
onto (8,16) or (2,16,16) unchanged — resharding is free by construction.
On multi-host this becomes one file per data-shard with the same manifest
(process_index keying), noted in the manifest for forward compatibility.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import ml_dtypes
import numpy as np

# numpy's .npy format can't represent ml_dtypes extension types — store them
# as same-width unsigned views and restore via the manifest's logical dtype
_EXT_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    for name, (ext, view) in _EXT_DTYPES.items():
        if arr.dtype == ext:
            return arr.view(view)
    return arr


def _from_storable(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _EXT_DTYPES:
        return arr.view(_EXT_DTYPES[logical_dtype][0])
    return arr


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_structure_repr(tree) -> str:
    return str(jax.tree_util.tree_structure(tree))


class Checkpointer:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Any, *, extra: dict | None = None,
             async_: bool = True) -> None:
        self.wait()
        leaves, _ = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        manifest = {
            "step": step,
            "n_leaves": len(host_leaves),
            "process_count": jax.process_count(),
            "structure": _tree_structure_repr(tree),
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
            "checksums": [hashlib.sha256(l.tobytes()).hexdigest()[:16]
                          for l in host_leaves],
            "extra": extra or {},
        }

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, leaf in enumerate(host_leaves):
                np.save(tmp / f"leaf_{i}.npy", _to_storable(leaf))
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():                          # re-save of same step
                shutil.rmtree(final)
            os.replace(tmp, final)                      # atomic commit
            self._gc()

        if async_:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.completed_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def completed_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and (p / "manifest.json").exists():
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def restore(self, step: int, target_tree: Any, *, shardings=None,
                verify: bool = True) -> tuple[Any, dict]:
        """Restore into the structure of ``target_tree`` (shapes must match).
        ``shardings``: optional pytree of NamedShardings (ELASTIC remesh)."""
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = _flatten(target_tree)
        assert manifest["n_leaves"] == len(leaves), "tree structure changed"
        out_leaves = []
        shard_leaves = (treedef.flatten_up_to(shardings)
                        if shardings is not None else [None] * len(leaves))
        for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
            arr = np.load(d / f"leaf_{i}.npy")
            arr = _from_storable(arr, manifest["dtypes"][i])
            if verify:
                cs = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
                if cs != manifest["checksums"][i]:
                    raise IOError(f"checksum mismatch on leaf {i} (corrupt ckpt)")
            assert tuple(arr.shape) == tuple(ref.shape), (i, arr.shape, ref.shape)
            arr = arr.astype(ref.dtype)
            out_leaves.append(jax.device_put(arr, shd) if shd is not None
                              else jax.device_put(arr))
        return treedef.unflatten(out_leaves), manifest["extra"]

    def restore_latest(self, target_tree: Any, *, shardings=None
                       ) -> tuple[int, Any, dict] | None:
        steps = self.completed_steps()
        if not steps:
            return None
        step = steps[-1]
        tree, extra = self.restore(step, target_tree, shardings=shardings)
        return step, tree, extra
