"""Hypothesis property tests on system invariants (task brief deliverable (c))."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.schema import Entry, Schema


@settings(max_examples=100, deadline=None)
@given(st.dictionaries(st.sampled_from(["a", "b", "c", "zz"]),
                       st.one_of(st.text(max_size=5), st.integers(), st.booleans())))
def test_schema_apply_idempotent(doc):
    """Enrichment is a fixpoint: apply(apply(doc)) == apply(doc)."""
    s = Schema("t", (Entry("a", "str", default="x"),
                     Entry("b", "int", default=3)))
    out1, errs1, _ = s.apply(doc)
    if errs1:
        return
    out2, errs2, _ = s.apply(out1)
    assert not errs2
    assert out1 == out2


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32,
                          allow_subnormal=False),   # TPUs/XLA flush denormals
                min_size=1, max_size=300),
       st.floats(-100, 100, allow_nan=False),
       st.floats(-100, 100, allow_nan=False))
def test_range_count_matches_numpy(xs, lo, hi):
    from repro.kernels.range_count import ops

    lo, hi = min(lo, hi), max(lo, hi)
    d = jnp.asarray(np.array(xs, np.float32))
    got = int(ops.range_count(d, lo, hi, interpret=True))
    arr = np.array(xs, np.float32)
    want = int(((arr >= np.float32(lo)) & (arr <= np.float32(hi))).sum())
    assert got == want


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 31), st.integers(1, 20), st.integers(0, 2**31 - 1))
def test_to_integral_bit_exact(n, rows, seed):
    from repro.kernels.to_integral import ref

    rng = np.random.default_rng(seed)
    m = rng.random((rows, n)) > 0.5
    got = np.asarray(ref.to_integral(jnp.asarray(m)))
    want = np.zeros(rows, np.uint32)
    for i in range(n):
        want |= m[:, i].astype(np.uint32) << np.uint32(i)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(1, 500), st.integers(0, 2**31 - 1))
def test_hadd_matches_numpy(rows, cols, seed):
    from repro.kernels.hadd import ops

    rng = np.random.default_rng(seed)
    v = rng.normal(size=(rows, cols)).astype(np.float32)
    got = np.asarray(ops.hadd(jnp.asarray(v), interpret=True))
    np.testing.assert_allclose(got, v.sum(-1), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 64), seed=st.integers(0, 2**31 - 1))
def test_softmax_rows_sum_to_one(lib_cpu, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(3, n)) * 5, jnp.float32)
    p = np.asarray(lib_cpu.ops.softmax(x), np.float64)
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)
    assert (p >= 0).all()


@settings(max_examples=25, deadline=None)
@given(tokens_pow=st.integers(1, 6), experts_pow=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1))
def test_moe_dispatch_combine_partition_of_unity(lib_cpu, tokens_pow,
                                                 experts_pow, seed):
    """With identity experts and ample capacity, dispatch+combine == identity
    (combine weights are a partition of unity)."""
    t, e = 2 ** tokens_pow, 2 ** min(experts_pow, 3)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(t, 4)), jnp.float32)
    logits = jnp.asarray(rng.normal(size=(t, e)), jnp.float32)
    k = min(2, e)
    w, idx = lib_cpu.ops.topk_gating(logits, k=k)
    xe, info = lib_cpu.ops.moe_dispatch(x, idx, w, n_experts=e,
                                        capacity=t * k)
    y = lib_cpu.ops.moe_combine(xe, info)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=2e-5,
                               atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rope_preserves_norm_and_is_relative(lib_cpu, seed):
    """RoPE invariants: norm preservation + relative-position property
    <q_m, k_n> depends only on (m - n)."""
    rng = np.random.default_rng(seed)
    d = 16
    q = rng.normal(size=(d,)).astype(np.float32)
    k = rng.normal(size=(d,)).astype(np.float32)

    def rot(x, pos):
        ang = pos * (10000.0 ** (-np.arange(d // 2) / (d // 2)))
        cos = jnp.asarray(np.cos(ang), jnp.float32)[None]
        sin = jnp.asarray(np.sin(ang), jnp.float32)[None]
        return np.asarray(lib_cpu.ops.rope_apply(jnp.asarray(x)[None], cos, sin))[0]

    np.testing.assert_allclose(np.linalg.norm(rot(q, 3)), np.linalg.norm(q),
                               rtol=1e-5)
    dot_a = rot(q, 5) @ rot(k, 2)
    dot_b = rot(q, 13) @ rot(k, 10)
    np.testing.assert_allclose(dot_a, dot_b, rtol=1e-3, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 40), st.integers(0, 2**31 - 1))
def test_ssd_state_linearity(t, seed):
    """The SSD recurrence is linear in x: y(x1+x2) = y(x1) + y(x2)."""
    from repro.kernels.ssd import ref

    rng = np.random.default_rng(seed)
    B, H, P, N = 1, 2, 4, 3
    x1 = jnp.asarray(rng.normal(size=(B, t, H, P)), jnp.float32)
    x2 = jnp.asarray(rng.normal(size=(B, t, H, P)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.8, 0.99, (B, t, H)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, t, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, t, N)), jnp.float32)
    y1, _ = ref.ssd_scan(x1, a, b, c)
    y2, _ = ref.ssd_scan(x2, a, b, c)
    y12, _ = ref.ssd_scan(x1 + x2, a, b, c)
    np.testing.assert_allclose(np.asarray(y12), np.asarray(y1) + np.asarray(y2),
                               rtol=1e-3, atol=1e-3)
