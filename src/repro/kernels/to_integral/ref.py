"""Pure-jnp oracle for to_integral / movemask (paper Fig 3/6)."""

from __future__ import annotations

import jax.numpy as jnp


def to_integral(mask):
    """(..., n<=32) bool -> (...,) uint32 bitmask (bit i = lane i)."""
    n = mask.shape[-1]
    assert n <= 32
    weights = jnp.left_shift(jnp.uint32(1), jnp.arange(n, dtype=jnp.uint32))
    return jnp.sum(mask.astype(jnp.uint32) * weights, axis=-1, dtype=jnp.uint32)
