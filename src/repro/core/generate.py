"""Generation GPO (paper Fig 5 ③) — renders the library source tree.

Two steps, as in the paper: (1) emit all SRU classes; (2) for every primitive
with a selected implementation, emit a helper "class template" with per-ctype
specializations plus a public function that forwards to it.

Stage-1 rendering (impl bodies are themselves Jinja2 templates over the SRU
data model) happens here, then identical rendered bodies are coalesced so one
specialization can cover many ctypes — the Python analogue of partial
specialization "reducing the number of specializations significantly".
"""

from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any

from . import engine
from .model import GenerationResult, GeneratedFile, PrimitiveDef, Selection


@dataclass
class _SpecView:
    fn_name: str
    body: str
    doc: str
    ctypes: list[str] = field(default_factory=list)


def _stage1(ctx: GenerationResult, prim: PrimitiveDef, sel: Selection) -> str:
    sru = ctx.targets[sel.target].as_render_dict()
    body = engine.render_stage1(
        sel.impl.implementation,
        sru=sru,
        ctype=sel.ctype,
        primitive=prim.name,
        params=prim.arg_names(),
    )
    return body if body.strip() else "pass"


def _render_helpers(ctx: GenerationResult, prim: PrimitiveDef, sel: Selection) -> str:
    if not sel.impl.helpers.strip():
        return ""
    sru = ctx.targets[sel.target].as_render_dict()
    return engine.render_stage1(
        sel.impl.helpers, sru=sru, ctype=sel.ctype, primitive=prim.name,
        params=prim.arg_names(),
    )


def _fwd_args(prim: PrimitiveDef) -> str:
    parts = []
    for p in prim.parameters:
        if "keyword_only" in p.attributes or p.default is not None:
            parts.append(f"{p.name}={p.name}")
        else:
            parts.append(p.name)
    return ", ".join(parts)


class GenerateGPO:
    name = "generate"

    def run(self, ctx: GenerationResult) -> GenerationResult:
        if ctx.errors:
            return ctx
        target = ctx.targets[ctx.config.target]
        tdict = target.as_render_dict()

        # step 1 — SRU class (paper: "all available SRUs are created as classes";
        # we emit the one relevant SRU — relevance filter, Fig 5 ②)
        ctx.files.append(GeneratedFile(
            relpath="_target.py",
            content=engine.render_template("sru.py.j2", target=tdict),
        ))

        # step 2 — primitives, grouped into modules
        groups: dict[str, list[str]] = collections.defaultdict(list)
        for name in ctx.selection:
            groups[ctx.primitives[name].group].append(name)

        cost_model: dict[str, dict[str, str]] = {}
        for group in sorted(groups):
            prim_views = []
            helper_blocks = []
            seen_helpers: set[str] = set()
            for name in sorted(groups[group]):
                prim = ctx.primitives[name]
                sels = ctx.selection[name]
                view = self._primitive_view(ctx, prim, sels)
                prim_views.append(view)
                for h in view.pop("_helpers"):
                    if h and h not in seen_helpers:
                        seen_helpers.add(h)
                        helper_blocks.append({"primitive": name, "code": h})
                # cost metadata: any selected impl may carry formulas
                for sel in sels.values():
                    if sel.impl.cost:
                        cost_model[name] = sel.impl.cost
                        break
            ctx.files.append(GeneratedFile(
                relpath=f"ops_{group}.py",
                content=engine.render_template(
                    "group_module.py.j2",
                    group=group,
                    target=tdict,
                    hw_flags=ctx.meta.get("hardware_flags", []),
                    helper_blocks=helper_blocks,
                    primitives=[_DotDict(v) for v in prim_views],
                ),
            ))

        ctx.files.append(GeneratedFile(
            relpath="ops.py",
            content=engine.render_template("ops.py.j2", groups=sorted(groups)),
        ))
        ctx.files.append(GeneratedFile(
            relpath="_cost.py",
            content=engine.render_template("cost.py.j2", cost_model=cost_model),
        ))
        ctx.files.append(GeneratedFile(
            relpath="__init__.py",
            content=engine.render_template(
                "init.py.j2",
                target=tdict,
                n_primitives=len(ctx.selection),
                groups=sorted(groups),
                primitive_names=sorted(ctx.selection),
                fingerprint=ctx.meta.get("fingerprint", ""),
            ),
        ))
        ctx.meta["groups"] = sorted(groups)
        return ctx

    # ------------------------------------------------------------------

    def _primitive_view(self, ctx: GenerationResult, prim: PrimitiveDef,
                        sels: dict[str, Selection]) -> dict[str, Any]:
        # stage-1 render every ctype, coalesce identical bodies
        by_body: dict[str, _SpecView] = {}
        helpers: list[str] = []
        order: list[str] = []
        for ctype, sel in sorted(sels.items()):
            body = _stage1(ctx, prim, sel)
            h = _render_helpers(ctx, prim, sel)
            if h:
                helpers.append(h)
            if body not in by_body:
                short = engine.dtype_info(ctype)["short"]
                by_body[body] = _SpecView(
                    fn_name=f"_{prim.name}__{short}",
                    body=body,
                    doc=(f"{prim.name} specialization "
                         f"[target={sel.target} native={sel.impl.is_native} "
                         f"score={sel.score} candidates={sel.candidates}]"),
                )
                order.append(body)
            by_body[body].ctypes.append(ctype)

        specs = []
        for body in order:
            sv = by_body[body]
            if len(sv.ctypes) == len(sels) and len(order) == 1:
                sv.fn_name = f"_{prim.name}__generic"
            specs.append(sv)

        table = {}
        for sv in specs:
            for ct in sv.ctypes:
                table[ct] = sv.fn_name

        any_sel = next(iter(sels.values()))
        dispatch_arg = prim.dispatch_param()
        default_ct = ctx.targets[any_sel.target].default_ctype
        if default_ct not in table:
            # fall back to any available specialization (also the dispatch
            # fallback slot, so it must always resolve)
            default_ct = next(iter(table))
        return {
            "name": prim.name,
            "brief": prim.brief,
            "sig": prim.signature(),
            "fwd_args": _fwd_args(prim),
            "dispatch_arg": dispatch_arg,
            "dispatch_desc": dispatch_arg or "static",
            "default_ctype": default_ct,
            "specializations": [
                {"fn_name": s.fn_name, "body": s.body, "doc": s.doc} for s in specs
            ],
            "table": table,
            "selection_note": "; ".join(
                f"{ct}->{sels[ct].impl.target_extension}"
                f"(score={sels[ct].score},loc={sels[ct].impl.loc},"
                f"native={sels[ct].impl.is_native},by={sels[ct].reason})"
                for ct in sorted(sels)
            ),
            "_helpers": helpers,
        }


class _DotDict(dict):
    __getattr__ = dict.__getitem__
