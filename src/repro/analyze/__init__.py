"""TSL-Check — the semantic static-analysis GPO (beyond-paper subsystem).

The paper's first pipeline operator only schema-validates the UPD; TSL-Check
is the semantic layer the paper's "valuable insights for assessing provided
functionality" claim implies. Four analyzer families over stable ``TSL0xx``
finding codes:

* cost channel   (TSL01x) — :mod:`.cost_check`
* coverage       (TSL02x) — :mod:`.coverage`
* Pallas tiling  (TSL03x) — :mod:`.tiling`
* body safety    (TSL04x) — :mod:`.safety`

Entry points: ``run_analysis(corpus)`` for programmatic use, ``AnalyzeGPO``
for pipeline insertion, ``python -m repro.core analyze`` from the CLI.
"""

from .cost_check import PRICED_PRIMITIVES, check_cost_channel
from .coverage import availability_matrix, check_coverage
from .findings import CODES, AnalysisReport, Code, Finding, SEVERITIES
from .gpo import AnalyzeGPO, default_kernel_root, run_analysis
from .render import RenderedBody, render_bodies
from .safety import check_safety
from .tiling import lint_kernel_file, lint_rendered_bodies

__all__ = [
    "AnalysisReport",
    "AnalyzeGPO",
    "CODES",
    "Code",
    "Finding",
    "PRICED_PRIMITIVES",
    "RenderedBody",
    "SEVERITIES",
    "availability_matrix",
    "check_cost_channel",
    "check_coverage",
    "check_safety",
    "default_kernel_root",
    "lint_kernel_file",
    "lint_rendered_bodies",
    "render_bodies",
    "run_analysis",
]
