"""Chunked-prefill slot engine tests (ISSUE 5).

Covers: token-for-token equivalence of chunked continuation prefill against
whole-prompt prefill across all four decode families (every chunk size shape:
chunk=1, ragged final chunk, chunk >= prompt with bucket padding); the
no-decode-stall acceptance property under a mixed trace with a long prompt
arriving mid-run; the compiled-shape bound (len(buckets) + 1 per family);
async arrival gating; proportional prefill/decode step-time attribution; and
hypothesis property tests for the length-bucketing policy.
"""

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs import get_config
from repro.serve import (BucketPolicy, CostModelAdmission, Request,
                         Scheduler, ServeEngine, upd_serve_defaults)


def _requests(cfg, gen_lens, prompt_len=8, seed=0, sla_s=None):
    rng = np.random.default_rng(seed)
    return [
        Request(rid=f"r{i}",
                tokens=rng.integers(0, cfg.vocab, prompt_len).astype(np.int32),
                gen_len=g, sla_s=sla_s)
        for i, g in enumerate(gen_lens)
    ]


# -- chunked continuation == whole-prompt prefill, all four families -----------


@pytest.mark.parametrize("arch,enc_len", [("qwen1.5-0.5b", None),
                                          ("rwkv6-7b", None),
                                          ("zamba2-7b", None),
                                          ("whisper-tiny", 8),
                                          ("internvl2-2b", None)])
def test_prefill_chunk_matches_whole_prompt(arch, enc_len):
    """For every family: running the prompt through prefill_chunk — at
    chunk=1, a ragged final chunk (prompt 9, chunk 3 -> 3 chunks; chunk 4 ->
    n_real=1 tail), and chunk >= prompt (one padded bucket-style chunk) —
    must reproduce whole-prompt prefill exactly: same last-token logits AND a
    decode step from the resulting state agrees."""
    import jax
    import jax.numpy as jnp

    jax.clear_caches()
    cfg = get_config(arch).reduced()
    from repro.nn.model import build_model

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt_len, max_len = 9, 24
    toks = rng.integers(0, cfg.vocab, (1, prompt_len)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    embeds = None
    if cfg.family == "vlm":
        embeds = jnp.ones((1, cfg.vision_prefix, cfg.d_model), cfg.dtype)
        batch["vision_embeds"] = embeds
    if cfg.family == "audio":
        embeds = jnp.ones((1, enc_len, cfg.d_model), cfg.dtype)
        batch["audio_embeds"] = embeds
    want_logits, want_state = model.prefill(params, batch, max_len)
    prefix = cfg.decode_prefix
    greedy = int(np.asarray(want_logits)[..., :cfg.vocab].argmax(-1)[0])
    next_tok = jnp.asarray([[greedy]], jnp.int32)
    want_dec, _ = model.decode_step(
        params, jax.tree.map(jnp.array, want_state), next_tok,
        jnp.int32(prompt_len + prefix))

    # (chunk, padded_len): minimal whole-chunk padding for chunk 1/3/4/16,
    # plus a bucket-style schedule (chunk 4, bucket 16) whose last TWO chunks
    # are all padding (n_real == 0) — the recurrent carries must survive them
    for chunk, padded_len in ((1, None), (3, None), (4, None), (16, None),
                              (4, 16)):
        st_c = model.init_decode_state(1, max_len, enc_len=enc_len)
        if padded_len is None:
            padded_len = ((prompt_len + chunk - 1) // chunk) * chunk
        padded = np.zeros((1, padded_len), np.int32)
        padded[:, :prompt_len] = toks
        fill, last = 0, None
        for ci in range(padded_len // chunk):
            seg = jnp.asarray(padded[:, ci * chunk:(ci + 1) * chunk])
            n_real = max(0, min(prompt_len - ci * chunk, chunk))
            logits, st_c = model.prefill_chunk(
                params, st_c, seg, jnp.int32(fill), jnp.int32(fill),
                n_real=jnp.int32(n_real),
                embeds=embeds if ci == 0 else None)
            pr = logits.shape[1] - seg.shape[1]     # vlm/audio prefix rows
            if ci == 0:
                fill += pr
            if n_real:
                last = np.asarray(logits)[:, pr + n_real - 1]
                fill += n_real
        assert fill == prompt_len + prefix
        np.testing.assert_allclose(last, np.asarray(want_logits),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"{arch} chunk={chunk}")
        # the state is equivalent too: one decode step agrees bit-for-bit up
        # to f32 accumulation — this exercises the padded cache rows beyond
        # the real fill (they must stay masked/ignored)
        got_dec, _ = model.decode_step(
            params, jax.tree.map(jnp.array, st_c), next_tok,
            jnp.int32(prompt_len + prefix))
        np.testing.assert_allclose(np.asarray(got_dec), np.asarray(want_dec),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"{arch} chunk={chunk} decode")


@pytest.mark.parametrize("arch,prompt_len", [("qwen1.5-0.5b", 5),
                                             ("qwen1.5-0.5b", 17),
                                             ("rwkv6-7b", 17),
                                             ("zamba2-7b", 17)])
def test_engine_bucket_padding_is_exact(arch, prompt_len):
    """End-to-end: a prompt shorter than its bucket served through the
    chunked engine emits the SAME tokens as an unbucketed, unchunked solo
    reference — bucket padding must never leak into the math. prompt 5 ->
    bucket 8 (partial final chunk); prompt 17 -> bucket 32 (4-chunk
    schedule whose LAST chunk is pure padding — the recurrent families'
    carries must pass through it untouched)."""
    import jax
    import jax.numpy as jnp

    jax.clear_caches()
    cfg = get_config(arch).reduced()
    from repro.nn.model import build_model

    max_len, gen = 40, 6
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))   # same seed as the engine
    rng = np.random.default_rng(0)
    target = rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)

    def pick(logits):
        return int(np.asarray(logits, np.float64)[..., :cfg.vocab].argmax(-1)[0])

    logits, st_solo = model.prefill(
        params, {"tokens": jnp.asarray(target[None])}, max_len)
    want = [pick(logits)]
    pos = prompt_len
    for _ in range(gen - 1):
        tok = jnp.asarray([[want[-1]]], jnp.int32)
        logits, st_solo = model.decode_step(params, st_solo, tok,
                                            jnp.int32(pos))
        want.append(pick(logits))
        pos += 1

    eng = ServeEngine(cfg, batch=2, max_len=max_len, seed=0)
    rep = eng.run([Request(rid="t", tokens=target, gen_len=gen)])
    want_bucket = 8 if prompt_len <= 8 else 32
    assert rep["per_request"][0]["bucket"] == want_bucket   # genuinely padded
    assert rep["outputs"]["t"] == want


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-7b"])
def test_concurrent_prefill_is_exact(arch):
    """A multi-chunk prompt prefilled WHILE a neighbour decodes must emit
    exactly the tokens it emits when served alone: decode steps running
    between its chunk steps must not touch the in-flight prefill (the donor
    lives outside the slot table until grafted). Covers both a KV-cache
    family (stale-position scatter corruption) and a recurrent family
    (state advanced by garbage tokens) — greedy sampling, so outputs are a
    pure function of the logits."""
    import jax

    jax.clear_caches()
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(0)
    long_tokens = rng.integers(0, cfg.vocab, 32).astype(np.int32)
    long_gen = 5

    # solo reference: same engine config, the long request alone
    eng = ServeEngine(cfg, batch=2, max_len=48, seed=0)
    want = eng.run([Request(rid="long", tokens=long_tokens,
                            gen_len=long_gen)])["outputs"]["long"]
    assert len(want) == long_gen

    # concurrent: a neighbour decodes throughout the long prompt's 4-chunk
    # prefill (arrival gating makes the overlap deterministic)
    jax.clear_caches()
    eng = ServeEngine(cfg, batch=2, max_len=48, seed=0)
    runner = Request(rid="runner",
                     tokens=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                     gen_len=40)
    late = Request(rid="long", tokens=long_tokens, gen_len=long_gen,
                   arrival_s=0.3)
    rep = eng.run([runner, late])
    assert rep["requests"] == 2
    long_steps = [e for e in rep["step_log"] if "long" in e["prefill_rids"]]
    assert long_steps and all(e["decoded"] >= 1 for e in long_steps), \
        "setup failed to overlap prefill with decode"
    assert rep["outputs"]["long"] == want


# -- acceptance: no decode stall + bounded compiled shapes ---------------------


def test_long_prompt_prefill_never_stalls_decode():
    """ISSUE 5 acceptance: with a >= 4x-bucket-length prompt arriving
    mid-run, every engine step that advances its prefill chunks also decodes
    one token for every running slot; padded_slot_steps_steady stays 0; and
    the engine's compiled shapes stay bounded by len(buckets) + 1."""
    import jax

    jax.clear_caches()
    cfg = get_config("qwen1.5-0.5b").reduced()
    eng = ServeEngine(cfg, batch=3, max_len=48)
    assert eng.policy.buckets == (8, 16, 32)    # filtered to the slot table
    rng = np.random.default_rng(0)
    # neighbours generate long enough to still be running through the whole
    # of the long prompt's chunk schedule (batch 3: the mid-run arrival takes
    # the free third slot, so its chunks genuinely share steps with decode)
    short = _requests(cfg, [30, 34], prompt_len=6, sla_s=600.0)
    # the long prompt: 4x the smallest bucket, arriving once decode is going
    long_req = Request(rid="long",
                       tokens=rng.integers(0, cfg.vocab, 32).astype(np.int32),
                       gen_len=4, sla_s=600.0, arrival_s=0.5)
    rep = eng.run(short + [long_req])

    assert rep["requests"] == 3
    assert rep["padded_slot_steps_steady"] == 0
    steps_by_rid = {e["rid"]: e["step"] for e in rep["admission_log"]}
    assert steps_by_rid["long"] > 0                     # arrived mid-run
    long_steps = [e for e in rep["step_log"]
                  if "long" in e["prefill_rids"]]
    assert len(long_steps) == 32 // rep["prefill_chunk"]
    # NO DECODE STALL: the running slot kept emitting in every chunk step
    assert all(e["decoded"] >= 1 for e in long_steps), long_steps
    # the long request's TTFT is measured from ITS arrival, not run start
    long_m = [m for m in rep["per_request"] if m["rid"] == "long"][0]
    assert long_m["bucket"] == 32
    assert long_m["ttft_s"] <= rep["wall_s"] - 0.5 + 1e-6
    # compiled-shape bound: one prefill-chunk shape + one decode shape,
    # <= len(buckets) + 1 (the jit-cache probe behind "the engine never runs
    # a shape it hasn't compiled")
    jc = rep["jit_cache"]
    assert jc["prefill_chunk"] + jc["decode"] <= len(rep["buckets"]) + 1, jc


def test_async_arrivals_gate_admission():
    """Requests with future arrival_s stay invisible to admission until the
    engine clock reaches them; the scheduler releases them in arrival
    order."""
    # scheduler-level: pending -> queue at release time
    sched = Scheduler(2)
    early = Request(rid="e", tokens=np.arange(4), gen_len=2)
    late = Request(rid="l", tokens=np.arange(4), gen_len=2, arrival_s=5.0)
    sched.submit(late, 0.0)
    sched.submit(early, 0.0)
    assert [r.rid for r in sched.queue] == ["e"]
    assert sched.next_arrival_s() == 5.0
    assert sched.release(1.0) == 0
    assert sched.release(5.0) == 1
    assert [r.rid for r in sched.queue] == ["e", "l"]
    assert sched.has_work()

    # engine-level: the late request is admitted at a later step
    import jax

    jax.clear_caches()
    cfg = get_config("qwen1.5-0.5b").reduced()
    eng = ServeEngine(cfg, batch=2, max_len=24)
    reqs = _requests(cfg, [10], prompt_len=6)
    reqs.append(Request(rid="late", tokens=np.zeros(6, np.int32), gen_len=3,
                        arrival_s=0.4))
    rep = eng.run(reqs)
    assert rep["requests"] == 2
    steps_by_rid = {e["rid"]: e["step"] for e in rep["admission_log"]}
    assert steps_by_rid["late"] > steps_by_rid["r0"]
    late_m = [m for m in rep["per_request"] if m["rid"] == "late"][0]
    # latency measured from arrival: strictly less than the run's wall clock
    assert late_m["latency_s"] < rep["wall_s"]


# -- shared-step time attribution ----------------------------------------------


def test_step_time_attribution_split():
    """ISSUE 5 satellite: shared-step wall time is split proportionally
    between prefill chunk tokens and decode tokens — a neighbour's prefill
    must not inflate a request's decode-t/s denominator."""
    sched = Scheduler(3)
    a = Request(rid="a", tokens=np.arange(4), gen_len=5)
    b = Request(rid="b", tokens=np.arange(4), gen_len=5)
    sched.submit(a, 0.0)
    sched.submit(b, 0.0)
    sched.place(sched.next_admissible(0.0), 0, step=0)
    sched.place(sched.next_admissible(0.0), 1, step=0)
    sched.first_token(0, 0.1)
    sched.first_token(1, 0.1)

    # one shared step: 8 prefill tokens (a chunk for some third request) + 2
    # decode tokens -> decode gets 2/10 of the wall, prefill 8/10
    pre, dec = sched.attribute_step_time(1.0, 8, [0, 1])
    assert pre == pytest.approx(0.8)
    assert dec == pytest.approx(0.2)
    assert sched.slots[0].metrics.decode_s == pytest.approx(0.2)
    assert sched.slots[1].metrics.decode_s == pytest.approx(0.2)

    # decode-only step: all of it is decode time
    sched.attribute_step_time(0.5, 0, [0, 1])
    assert sched.slots[0].metrics.decode_s == pytest.approx(0.7)

    # finish() computes decode-t/s from ATTRIBUTED decode seconds, not from
    # latency - ttft (which would include the neighbour's prefill wall)
    for _ in range(4):
        sched.step_done(0)
    m = sched.finish(0, 10.0)
    assert m.decode_tokens_per_s == pytest.approx(4 / 0.7)
    # the un-attributed fallback would have been 4 / (10 - 0.1)
    assert m.decode_tokens_per_s > 4 / (10.0 - 0.1)

    # zero-work step is a no-op
    assert sched.attribute_step_time(1.0, 0, []) == (0.0, 0.0)


def test_engine_attributes_prefill_and_decode_time():
    """Engine-level: per-request prefill_s/decode_s are populated and a
    request that decoded while a long neighbour prefilled reports decode_s
    well under its wall-clock decode window."""
    import jax

    jax.clear_caches()
    cfg = get_config("qwen1.5-0.5b").reduced()
    eng = ServeEngine(cfg, batch=2, max_len=48)
    rng = np.random.default_rng(0)
    reqs = _requests(cfg, [20], prompt_len=6)
    reqs.append(Request(rid="long",
                        tokens=rng.integers(0, cfg.vocab, 32).astype(np.int32),
                        gen_len=3, arrival_s=0.2))
    rep = eng.run(reqs)
    assert rep["requests"] == 2
    per = {m["rid"]: m for m in rep["per_request"]}
    assert per["long"]["prefill_s"] > 0
    assert per["r0"]["decode_s"] > 0
    # r0's attributed decode time excludes the long prefill's share: it is
    # strictly smaller than its naive wall window (latency - ttft)
    wall_window = per["r0"]["latency_s"] - per["r0"]["ttft_s"]
    assert per["r0"]["decode_s"] < wall_window
    assert per["r0"]["decode_tokens_per_s"] > \
        (per["r0"]["tokens_out"] - 1) / wall_window


# -- length-bucketing policy property tests ------------------------------------


def test_bucket_policy_validation_and_upd_defaults():
    with pytest.raises(ValueError, match="multiples"):
        BucketPolicy((8, 12), 8)
    with pytest.raises(ValueError, match="sorted"):
        BucketPolicy((16, 8), 8)
    d = upd_serve_defaults()
    pol = BucketPolicy.from_upd()
    assert pol.buckets == tuple(d["buckets"])
    assert pol.chunk == d["chunk"]
    assert all(b % pol.chunk == 0 for b in pol.buckets)


@settings(max_examples=100, deadline=None)
@given(st.frozensets(st.integers(1, 64), min_size=1, max_size=6),
       st.integers(1, 600), st.integers(1, 600))
def test_bucket_assignment_monotone_and_minimal(mults, p1, p2):
    """Monotone: longer prompts never get smaller buckets. Minimal: nobody
    is padded past the next bucket — the assigned bucket is the smallest
    declared size that fits."""
    chunk = 4
    pol = BucketPolicy(sorted(m * chunk for m in mults), chunk)
    b1, b2 = pol.assign(p1), pol.assign(p2)
    if p1 <= p2 and b1 is not None and b2 is not None:
        pass  # ordering asserted below via minimality
    if p1 <= p2 and b2 is not None and b1 is None:
        raise AssertionError("shorter prompt refused while longer admitted")
    if b1 is not None:
        assert b1 >= p1
        smaller = [b for b in pol.buckets if b < b1]
        assert all(b < p1 for b in smaller)     # no smaller bucket fits
        assert pol.n_chunks(b1) * chunk == b1
    if p1 <= p2 and b1 is not None and b2 is not None:
        assert b1 <= b2                          # monotone


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 80), st.integers(1, 8)),
                min_size=2, max_size=8),
       st.integers(0, 2 ** 31))
def test_refusal_reasons_stable_under_arrival_permutation(specs, shuffle_seed):
    """Admission at a fixed clock is a pure function of the request: the SET
    of refused rids and their reasons must not depend on arrival order."""
    import random

    cfg = get_config("qwen1.5-0.5b").reduced()
    pol = BucketPolicy((8, 16), 8)
    adm = CostModelAdmission(cfg, batch=2, max_len=20, policy=pol)
    reqs = [Request(rid=f"q{i}", tokens=np.zeros(p, np.int32), gen_len=g)
            for i, (p, g) in enumerate(specs)]

    def refusals(order):
        sched = Scheduler(len(order), admission=adm)
        for r in order:
            r.bucket = 0
            sched.submit(r, 0.0)
        while sched.next_admissible(0.0) is not None:
            pass
        return {r.rid: r.reason for r in sched.refused}

    base = refusals(list(reqs))
    shuffled = list(reqs)
    random.Random(shuffle_seed).shuffle(shuffled)
    assert refusals(shuffled) == base
