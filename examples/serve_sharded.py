"""Mesh-sharded serving demo: the slot-table engine on a (data=2, model=4)
``jax.sharding`` mesh, producing tokens IDENTICAL to the 1-device engine.

Parameters shard by the ``repro.dist.sharding`` rules (row/col TP on the
``model`` axis, output-projection flip, replicated norms); slot-table state
shards batch-on-``data`` / sequence-on-``model`` per the family's declared
page axes. The per-step jits compile once against ``NamedSharding``-annotated
donors, so every steady-state step runs with ZERO resharding (asserted from
the report's audit counter), and the paged prefix store still dedups the
shared system prompt across the mesh.

Runs on CPU with simulated devices — the XLA flag must be set before jax
initializes:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_sharded.py
"""

import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.serve import PagedConfig, Request, ServeEngine  # noqa: E402

N_REQUESTS = 6
SYSTEM_LEN = 16          # shared system prompt, page-aligned (page_size=8)
UNIQUE_LEN = 5
GEN_LEN = 5
MAX_LEN = 64
PAGE = 8


def make_requests(cfg):
    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab, SYSTEM_LEN).astype(np.int32)
    reqs = []
    for i in range(N_REQUESTS):
        toks = np.concatenate(
            [system, rng.integers(0, cfg.vocab, UNIQUE_LEN).astype(np.int32)])
        reqs.append(Request(rid=f"r{i}", tokens=toks, gen_len=GEN_LEN,
                            shared_prefix_len=SYSTEM_LEN))
    return reqs


def run(cfg, mesh):
    jax.clear_caches()
    eng = ServeEngine(cfg, batch=2, max_len=MAX_LEN, seed=0, mesh=mesh,
                      paged=PagedConfig(prefix_sharing=True, fused=True,
                                        page_size=PAGE))
    rep = eng.run(make_requests(cfg))
    return {rid: tuple(t) for rid, t in rep["outputs"].items()}, rep


def main():
    assert len(jax.devices()) >= 8, (
        "need XLA_FLAGS=--xla_force_host_platform_device_count=8, got "
        f"{len(jax.devices())} device(s)")
    cfg = get_config("qwen1.5-0.5b").reduced()
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("data", "model"))

    base, _ = run(cfg, mesh=None)
    toks, rep = run(cfg, mesh=mesh)

    m = rep["mesh"]
    print(f"[example] mesh axes {m['axes']} = {m['shards']} shards, "
          f"{m['param_bytes_per_shard'] / 1e3:.1f} kB params/shard, "
          f"{m['hbm_resident_bytes_per_shard'] / 1e3:.1f} kB resident/shard")
    print(f"[example] collective traffic {m['comms_bytes_per_step'] / 1e3:.1f} "
          f"kB/step over the model axis (UPD 'comms' term)")

    # the headline: token-for-token identical to the 1-device engine
    assert toks == base, "mesh outputs diverged from 1-device outputs"
    print(f"[example] {N_REQUESTS} requests token-for-token identical "
          f"to the 1-device engine ({GEN_LEN} tokens each)")

    # compiled once against rule-sharded donors: zero steady-state resharding
    assert m["reshard_events"] == 0, m
    print("[example] reshard events: 0 (donors pinned to the rule shardings)")

    # prefix sharing keeps working across the mesh
    pg = rep["paged"]
    assert pg["prefix_hits"] >= 1, pg
    assert pg["prefix_hits"] == N_REQUESTS - 1, pg
    print(f"[example] prefix store on-mesh: {pg['prefix_hits']} hits / "
          f"{pg['prefix_misses']} miss (system prompt prefilled once)")


if __name__ == "__main__":
    main()
