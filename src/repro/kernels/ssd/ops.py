"""Chunked SSD (Mamba2's state-space-duality algorithm), MXU-shaped.

TPU adaptation of the GPU SSD kernel (DESIGN.md §2): the chunk-local quadratic
part becomes two dense (L×L)·(L×·) matmuls that map onto the MXU, and the
cross-chunk recurrence is a lax.scan over chunk states — a "linear attention
with decay" decomposition:

    y = (M ⊙ (C Bᵀ)) X  +  (decay · C) h_prev
    M[t,s] = prod_{j=s+1..t} a_j  (causal, log-space cumulative sums)

Sub-quadratic: O(T·L) instead of O(T²) — this is the primitive that makes the
`long_500k` cell feasible for zamba2/rwkv6-family archs.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref

# Python float, NOT jnp.float32 (see wkv6/ops.py: hoisted-constant dispatch bug)
_NEG = -1e30


@partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked(x, a, b, c, *, h0=None, chunk: int = 128):
    """Same contract as ref.ssd_scan, computed chunk-parallel.

    x (B,T,H,P), a (B,T,H), b,c (B,T,N) -> y (B,T,H,P), h_final (B,H,P,N)."""
    bsz, t, nh, p = x.shape
    n = b.shape[-1]
    L = min(chunk, t)
    pad = (-t) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    tt = t + pad
    nc = tt // L

    xf = x.astype(jnp.float32).reshape(bsz, nc, L, nh, p)
    af = a.astype(jnp.float32).reshape(bsz, nc, L, nh)
    bf = b.astype(jnp.float32).reshape(bsz, nc, L, n)
    cf = c.astype(jnp.float32).reshape(bsz, nc, L, n)

    la = jnp.log(jnp.maximum(af, 1e-20))           # (B,C,L,H)
    cum = jnp.cumsum(la, axis=2)                    # log prod_{j<=t} a_j
    # M[t,s] = exp(cum_t - cum_s) for s <= t, else 0 (strictly: prod_{s+1..t})
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,C,L,L,H)
    causal = jnp.tril(jnp.ones((L, L), bool))
    seg = jnp.where(causal[None, None, :, :, None], seg, _NEG)
    m = jnp.exp(seg)                                 # (B,C,L,L,H)

    # intra-chunk: y_intra = (M ⊙ (C Bᵀ)) X    -- two MXU matmuls
    cb = jnp.einsum("bctn,bcsn->bcts", cf, bf)       # (B,C,L,L)
    g = cb[..., None] * m                            # (B,C,L,L,H)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", g, xf)

    # chunk-boundary states: s_c = sum_s (prod_{j=s+1..L} a_j) x_s ⊗ b_s
    tail = cum[:, :, -1:, :] - cum                   # log prod_{j=t+1..L}
    w = jnp.exp(tail)                                # (B,C,L,H)
    chunk_state = jnp.einsum("bcth,bcthp,bctn->bchpn", w, xf, bf)
    a_chunk = jnp.exp(cum[:, :, -1, :])              # total chunk decay (B,C,H)

    if h0 is None:
        h0 = jnp.zeros((bsz, nh, p, n), jnp.float32)

    def scan_fn(hprev, inp):
        s_c, a_c = inp                               # (B,H,P,N), (B,H)
        hnew = a_c[:, :, None, None] * hprev + s_c
        return hnew, hprev

    h_final, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (chunk_state.transpose(1, 0, 2, 3, 4), a_chunk.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)       # (B,C,H,P,N) state BEFORE chunk

    # inter-chunk: y_inter[t] = (prod_{j<=t} a_j) * (c_t @ h_prev)
    decay_in = jnp.exp(cum)                          # (B,C,L,H)
    y_inter = jnp.einsum("bcth,bctn,bchpn->bcthp", decay_in, cf, h_prevs)

    y = (y_intra + y_inter).reshape(bsz, tt, nh, p)[:, :t]
    return y.astype(x.dtype), h_final


ssd_scan = ref.ssd_scan
ssd_decode_step = ref.ssd_decode_step

__all__ = ["ssd_chunked", "ssd_scan", "ssd_decode_step", "ref"]
