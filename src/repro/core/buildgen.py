"""Build-environment generation GPO (paper §4.2, Fig 7).

The paper generates CMake glue so the library integrates with zero effort.
The Python/JAX analogue: a ``pyproject.toml`` for the generated package, a
JSON build manifest (file list + selection provenance + fingerprint — what
CMake's dependency tracking gave the paper), and an import shim.
"""

from __future__ import annotations

import json

from .model import GenerationResult, GeneratedFile

_PYPROJECT = """[project]
name = "{pkg}"
version = "0.1.0"
description = "Generated TSL (target {target}) — TSLGen-JAX"
dependencies = ["jax", "numpy"]

[tool.setuptools]
packages = ["{pkg}"]
"""


class BuildGenGPO:
    name = "buildgen"

    def run(self, ctx: GenerationResult) -> GenerationResult:
        if ctx.errors:
            return ctx
        manifest = {
            "generator": "TSLGen-JAX",
            "target": ctx.config.target,
            "package": ctx.config.package_name,
            "fingerprint": ctx.meta.get("fingerprint", ""),
            "hardware_flags": ctx.meta.get("hardware_flags", []),
            "cherry_picked": sorted(ctx.config.only) if ctx.config.only else None,
            "files": sorted(f.relpath for f in ctx.files),
            "primitives": {
                name: {
                    ctype: {
                        "score": sel.score,
                        "loc": sel.impl.loc,
                        "is_native": sel.impl.is_native,
                        "candidates": sel.candidates,
                        "selected_by": sel.reason,
                        "required_flags": list(sel.impl.flags),
                    }
                    for ctype, sel in sorted(sels.items())
                }
                for name, sels in sorted(ctx.selection.items())
            },
            "warnings": ctx.warnings,
        }
        ctx.files.append(GeneratedFile(
            relpath="_manifest.json",
            content=json.dumps(manifest, indent=1),
            kind="build",
        ))
        ctx.files.append(GeneratedFile(
            relpath="pyproject.toml",
            content=_PYPROJECT.format(pkg=ctx.config.package_name,
                                      target=ctx.config.target),
            kind="build",
        ))
        return ctx
