"""Continuous-batching serving subsystem.

- ``scheduler``: request queue, slot-table lifecycle, SLA accounting,
  ``lib.cost()``-driven admission (host-side control plane, no jax);
- ``slots``: slot-level state access — read a slot back out, validate a
  donor against the slot table (the insert/reset surgery itself lives on
  ``Model.insert_slot``/``reset_slot``, uniform over all four families);
- ``engine``: the per-step continuous-batching loop (jit-stable shapes,
  per-slot positions, TTFT / decode-t/s / SLA metrics);
- ``spec``: speculative decoding — drafters (n-gram prompt-lookup / small
  draft model), the longest-accepted-prefix rule, and UPD-cost-priced
  per-slot speculation depth (``attention_verify``'s serve block + cost
  terms drive both the span bound and the depth decision).

See README.md in this directory for the slot/state-surgery contract.
"""

from .engine import SamplingConfig, ServeEngine
from .scheduler import (BucketPolicy, CostModelAdmission, Request,
                        RequestMetrics, Scheduler, upd_serve_defaults)
from .slots import assert_span_fits, take_slot, validate_donor
from .spec import (DraftModelDrafter, NGramDrafter, SpeculationConfig,
                   SpeculationPolicy, accept_span, upd_verify_defaults)

__all__ = [
    "BucketPolicy",
    "CostModelAdmission",
    "DraftModelDrafter",
    "NGramDrafter",
    "Request",
    "RequestMetrics",
    "SamplingConfig",
    "Scheduler",
    "ServeEngine",
    "SpeculationConfig",
    "SpeculationPolicy",
    "accept_span",
    "assert_span_fits",
    "take_slot",
    "upd_serve_defaults",
    "upd_verify_defaults",
    "validate_donor",
]
