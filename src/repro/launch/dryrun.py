import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY jax-touching import: jax locks the
# device count at first init. 512 host devices back the production meshes
# (16,16) and (2,16,16). This env is dryrun-only by design — tests/benches
# see one device.

"""Multi-pod dry-run (task brief deliverable (e)).

For every (architecture × shape × mesh): build the step function, jit with
explicit in/out shardings, ``.lower().compile()``, print memory_analysis() and
cost_analysis(), parse collective bytes from the compiled HLO, and write a
JSON record under experiments/dryrun/ for EXPERIMENTS.md §Dry-run/§Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, applicable, get_config
from repro.configs.registry import ARCH_IDS
from repro.dist import sharding
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.nn.model import build_model
from repro.train.optimizer import OptConfig
from repro.train.step import init_train_state, make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# Production train_4k execution configs (per-device HBM fit on v5e 16 GiB):
# microbatch counts keep per-device live activations (L x B/dp/uB x S x D x 2B
# + logits) under budget; int8 Adam moments make the MoE giants fit (train
# state = 2+1+1 B/param instead of 2+4+4). Justified per arch in
# EXPERIMENTS.md §Dry-run.
TRAIN_DEFAULTS: dict[str, dict] = {
    "mistral-large-123b": {"microbatches": 16, "moment_dtype": "int8"},
    "yi-34b": {"microbatches": 8},
    "grok-1-314b": {"microbatches": 8, "moment_dtype": "int8"},
    "arctic-480b": {"microbatches": 8, "moment_dtype": "int8"},
    "qwen3-14b": {"microbatches": 4},
    "zamba2-7b": {"microbatches": 4},
    "rwkv6-7b": {"microbatches": 4},
    "internvl2-2b": {"microbatches": 2},
    "qwen1.5-0.5b": {"microbatches": 1},
    "whisper-tiny": {"microbatches": 1},
}


def _mesh_tag(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)


def depth_variants(cfg):
    """(cfg_depth1, cfg_depth2, units): the two unrolled shallow lowerings
    used to correct XLA's count-loop-body-once cost analysis, plus the number
    of repeating units in the full model (fractional for zamba's remainder
    layers — documented approximation)."""
    if cfg.family == "hybrid":
        k = cfg.attn_every
        return (cfg.replace(n_layers=k), cfg.replace(n_layers=2 * k),
                cfg.n_layers / k)
    if cfg.family == "audio":
        return (cfg.replace(n_layers=1, n_enc_layers=1),
                cfg.replace(n_layers=2, n_enc_layers=2), cfg.n_layers)
    return cfg.replace(n_layers=1), cfg.replace(n_layers=2), cfg.n_layers


def build_lowerable(cfg, shape_name: str, mesh, opt_overrides=None):
    """Returns (fn, example_args pytree of ShapeDtypeStruct, in_shardings,
    out_shardings, meta)."""
    cell = SHAPES[shape_name]
    ok, reason = applicable(cfg, cell)
    if not ok:
        return None, reason
    model = build_model(cfg)
    overrides = opt_overrides or {}

    if cell.kind == "train":
        opt_cfg = OptConfig(moment_dtype=overrides.get("moment_dtype", "float32"))
        step = make_train_step(model, opt_cfg,
                               microbatches=overrides.get("microbatches", 1))
        state_shapes = jax.eval_shape(
            lambda: init_train_state(model, opt_cfg, jax.random.PRNGKey(0)))
        pspec = sharding.param_shardings(mesh, state_shapes["params"])

        def moments_sharding(mtree):
            """Optimizer moments follow the param sharding leaf-for-leaf.
            int8 moments replace each leaf with {"q": int8 (param shape),
            "scale": f32 (last dim 1)} — q inherits the param spec, scale
            drops the last axis."""
            def match(ps, m):
                if isinstance(m, dict) and set(m) == {"q", "scale"}:
                    qspec = ps.spec
                    sspec = P(*qspec[:-1], None) if len(qspec) else P()
                    return {"q": NamedSharding(mesh, qspec),
                            "scale": NamedSharding(mesh, sspec)}
                return ps
            return jax.tree.map(
                match, pspec, mtree,
                is_leaf=lambda x: isinstance(x, NamedSharding))

        state_shardings = {
            "params": pspec,
            "opt": {
                "m": moments_sharding(state_shapes["opt"]["m"]),
                "v": moments_sharding(state_shapes["opt"]["v"]),
                "count": NamedSharding(mesh, P()),
            },
        }
        batch_shapes = model.input_specs(cell)
        batch_shardings = sharding.batch_shardings(mesh, batch_shapes)
        fn = step
        args = (state_shapes, batch_shapes)
        in_sh = (state_shardings, batch_shardings)
        out_sh = (state_shardings, None)
        meta = {"step": "train_step"}
    elif cell.kind == "prefill":
        specs = model.input_specs(cell)
        params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        pshard = sharding.param_shardings(mesh, params_shapes)

        def fn(params, batch):
            return model.prefill(params, batch, cell.seq_len)

        state_shapes = jax.eval_shape(
            lambda: model.init_decode_state(cell.global_batch, cell.seq_len))
        out_state_sh = sharding.state_shardings(mesh, state_shapes)
        args = (params_shapes, specs)
        in_sh = (pshard, sharding.batch_shardings(mesh, specs))
        out_sh = (None, out_state_sh)
        meta = {"step": "prefill_step"}
    else:  # decode
        specs = model.input_specs(cell)
        params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        pshard = sharding.param_shardings(mesh, params_shapes)
        state_shapes = specs["state"]
        sshard = sharding.state_shardings(mesh, state_shapes)

        def fn(params, state, tokens, pos):
            return model.decode_step(params, state, tokens, pos)

        args = (params_shapes, state_shapes, specs["tokens"], specs["pos"])
        in_sh = (pshard, sshard,
                 sharding.batch_shardings(mesh, {"t": specs["tokens"]})["t"],
                 NamedSharding(mesh, P()))
        out_sh = (None, sshard)
        meta = {"step": "serve_step"}
    return (fn, args, in_sh, out_sh, meta), ""


def _lower_compile(cfg, shape_name, mesh, opt_overrides):
    built, reason = build_lowerable(cfg, shape_name, mesh, opt_overrides)
    if built is None:
        return None, reason
    fn, args, in_sh, out_sh, meta = built
    with jax.set_mesh(mesh):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return (lowered, compiled, meta), ""


def _cost_tuple(compiled):
    cost = compiled.cost_analysis() or {}
    coll = roofline.parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def run_cell(arch_id: str, shape_name: str, mesh, *, verbose: bool = True,
             opt_overrides=None, tag: str = "",
             corrected_terms: bool = True) -> dict:
    from repro.nn import flags as nn_flags

    cell = SHAPES[shape_name]
    cfg = get_config(arch_id)
    chips = mesh.devices.size
    record: dict = {
        "arch": arch_id, "shape": shape_name, "mesh": _mesh_tag(mesh),
        "chips": chips, "kind": cell.kind, "tag": tag,
    }
    # merge per-arch production defaults with explicit overrides
    defaults = dict(TRAIN_DEFAULTS.get(arch_id, {})) if cell.kind == "train" else {}
    defaults.update({k: v for k, v in (opt_overrides or {}).items()
                     if v not in (None, "default")})
    opt_overrides = defaults
    record["exec_config"] = dict(opt_overrides)
    t0 = time.perf_counter()
    try:
        # 1) the REQUIRED full-depth lowering: proves the sharding config is
        #    coherent; memory_analysis is exact here (all buffers allocated)
        out, reason = _lower_compile(cfg, shape_name, mesh, opt_overrides)
        if out is None:
            record["status"] = "skipped"
            record["reason"] = reason
            return record
        lowered, compiled, meta = out
        record.update(meta)
        t_full = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        raw_flops, raw_bytes, raw_coll = _cost_tuple(compiled)

        # 2) depth-1/depth-2 UNROLLED lowerings: XLA cost analysis counts a
        #    while-loop body once, so scan-based costs undercount by ~L. The
        #    per-layer delta extrapolates the true cost linearly in depth.
        corrected = None
        if corrected_terms:
            cfg1, cfg2, units = depth_variants(cfg)
            # variants lower the WHOLE global batch in one microbatch: the
            # microbatch scan is also a while loop XLA counts once, so µB=1
            # gives the exact per-step math cost (memory_analysis above keeps
            # the production µB). FSDP weight re-gathers under µB>1 are a
            # modeled note in §Roofline, not in these terms.
            var_overrides = dict(opt_overrides or {})
            var_overrides["microbatches"] = 1
            nn_flags.SCAN_UNROLL = True
            try:
                (l1, c1, _), _ = _lower_compile(cfg1, shape_name, mesh, var_overrides)
                f1, b1, coll1 = _cost_tuple(c1)
                (l2, c2, _), _ = _lower_compile(cfg2, shape_name, mesh, var_overrides)
                f2, b2, coll2 = _cost_tuple(c2)
            finally:
                nn_flags.SCAN_UNROLL = False
            # clamp at the depth-1 cost: per-layer deltas can be slightly
            # negative on decode cells (layer-count-independent setup work
            # dominates and compiles non-monotonically)
            ext = lambda x1, x2: max(x1, x1 + (units - 1.0) * (x2 - x1), 0.0)
            corrected = {
                "flops": ext(f1, f2),
                "bytes accessed": ext(b1, b2),
            }
            coll_eff = ext(coll1.effective_bytes, coll2.effective_bytes)
            coll_counts = {
                k: round(ext(coll1.counts.get(k, 0), coll2.counts.get(k, 0)), 1)
                for k in set(coll1.counts) | set(coll2.counts)}
            coll_bytes = {
                k: ext(coll1.bytes_by_kind.get(k, 0), coll2.bytes_by_kind.get(k, 0))
                for k in set(coll1.bytes_by_kind) | set(coll2.bytes_by_kind)}
            coll_obj = roofline.CollectiveStats(coll_counts, coll_bytes, coll_eff)
            record["depth_extrapolation"] = {
                "units": units, "depth1_flops": f1, "depth2_flops": f2}
        else:
            coll_obj = raw_coll
            corrected = {"flops": raw_flops, "bytes accessed": raw_bytes}

        terms = roofline.roofline_terms(corrected, coll_obj)
        mf = roofline.model_flops(cfg, cell, chips)
        hlo_f = terms["hlo_flops_per_device"]
        record.update({
            "status": "ok",
            "lower_compile_s": round(t_full, 2),
            "memory_analysis": _mem_dict(mem),
            "raw_scan_flops_per_device": raw_flops,
            "raw_scan_bytes_per_device": raw_bytes,
            "raw_scan_collectives": raw_coll.as_dict(),
            "collectives": coll_obj.as_dict(),
            "roofline": terms,
            "model_flops_per_device": mf,
            "useful_flops_ratio": (mf / hlo_f) if hlo_f else None,
            "params": cfg.param_count(),
            "params_active": cfg.param_count(active_only=True),
        })
        if verbose:
            print(f"[dryrun] {arch_id} x {shape_name} x {_mesh_tag(mesh)}: OK "
                  f"({t_full:.1f}s) dominant={terms['dominant']} "
                  f"bound={terms['roofline_bound_s']:.4f}s "
                  f"useful={record['useful_flops_ratio'] and round(record['useful_flops_ratio'],3)}")
            print(f"  memory_analysis: {record['memory_analysis']}")
            print(f"  corrected: flops/dev={hlo_f:.3e} "
                  f"bytes/dev={terms['hlo_bytes_per_device']:.3e}")
            print(f"  collectives: {coll_obj.counts}")
    except Exception as e:  # noqa: BLE001 — a failure here is a finding
        record["status"] = "failed"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch_id} x {shape_name} x {_mesh_tag(mesh)}: "
                  f"FAILED — {record['error']}")
    return record


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    args_b = out.get("argument_size_in_bytes", 0)
    temp_b = out.get("temp_size_in_bytes", 0)
    out["total_hbm_gib_per_device"] = round((args_b + temp_b) / 2**30, 3)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--moment-dtype", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel TP residual stream")
    ap.add_argument("--ep", action="store_true",
                    help="expert parallelism (expert dim on data axes)")
    args = ap.parse_args(argv)

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(make_production_mesh(multi_pod=False))
    if args.mesh in ("multi", "both"):
        meshes.append(make_production_mesh(multi_pod=True))

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    overrides = {"moment_dtype": args.moment_dtype,
                 "microbatches": args.microbatches}
    if args.sp or args.ep:
        from repro.nn import flags as nn_flags

        nn_flags.SEQUENCE_PARALLEL = args.sp
        nn_flags.EXPERT_PARALLEL = args.ep
    n_fail = 0
    for arch_id, shape_name in cells:
        for mesh in meshes:
            rec = run_cell(arch_id, shape_name, mesh, opt_overrides=overrides,
                           tag=args.tag)
            suffix = f"_{args.tag}" if args.tag else ""
            out = OUT_DIR / f"{arch_id}_{shape_name}_{_mesh_tag(mesh)}{suffix}.json"
            out.write_text(json.dumps(rec, indent=1))
            if rec["status"] == "failed":
                n_fail += 1
    print(f"[dryrun] complete; {n_fail} failures")
    return n_fail


if __name__ == "__main__":
    raise SystemExit(main())
