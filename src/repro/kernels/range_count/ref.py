"""Pure-jnp oracle for the paper's range-count case study (Fig 8).

`filter_count(data, l, u)` = number of elements with l <= x <= u.
This is also the cpu_xla TSL implementation of the fused primitive.
"""

from __future__ import annotations

import jax.numpy as jnp


def range_count(data, low, high):
    data = data.reshape(-1)
    mask = jnp.logical_and(data >= low, data <= high)
    return jnp.sum(mask.astype(jnp.int32))
