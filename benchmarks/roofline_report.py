"""Render the §Dry-run / §Roofline tables in EXPERIMENTS.md from the JSON
records written by launch/dryrun.py."""

from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load_records(mesh: str = "16x16", tag: str = "") -> list[dict]:
    recs = []
    for f in sorted(DRYRUN_DIR.glob(f"*_{mesh}{('_' + tag) if tag else ''}.json")):
        r = json.loads(f.read_text())
        if tag and r.get("tag") != tag:
            continue
        if not tag and r.get("tag"):
            continue
        recs.append(r)
    return recs


def _fmt_s(x) -> str:
    if x is None:
        return "—"
    if x >= 1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x*1e3:.1f}m"
    return f"{x*1e6:.0f}µ"


def roofline_table(mesh: str = "16x16", tag: str = "") -> str:
    rows = ["| arch | shape | status | compute | memory(adj) | collective | "
            "dominant | MODEL/HLO flops | HBM GiB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in load_records(mesh, tag):
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skip ({r['reason'][:36]}…) "
                        f"| — | — | — | — | — | — |")
            continue
        if r["status"] == "failed":
            rows.append(f"| {r['arch']} | {r['shape']} | FAILED | — | — | — | — | — | — |")
            continue
        t = r["roofline"]
        mem = r.get("memory_analysis", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {_fmt_s(t['compute_s'])} | "
            f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
            f"**{t['dominant']}** | "
            f"{(r.get('useful_flops_ratio') or 0):.3f} | "
            f"{mem.get('total_hbm_gib_per_device', 0):.1f} |")
    return "\n".join(rows)


def summary(mesh: str = "16x16") -> dict:
    recs = load_records(mesh)
    ok = [r for r in recs if r["status"] == "ok"]
    return {
        "cells": len(recs),
        "ok": len(ok),
        "skipped": sum(r["status"] == "skipped" for r in recs),
        "failed": sum(r["status"] == "failed" for r in recs),
        "dominant": {d: sum(r["roofline"]["dominant"] == d for r in ok)
                     for d in ("compute", "memory", "collective")},
    }


def run() -> list[str]:
    out = []
    for mesh in ("16x16", "2x16x16"):
        s = summary(mesh)
        print(f"roofline_{mesh},0,{json.dumps(s)}")
        out.append(f"{mesh}: {s}")
    return out


if __name__ == "__main__":
    print(roofline_table("16x16"))
    run()
