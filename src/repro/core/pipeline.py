"""GPO pipeline (paper Fig 5 ①).

*"We designed our generator core as a pipeline consisting of multiple
generator pipeline operators (GPO), where every GPO depends on the result of
the previous one. That way, the GPOs remain exchangeable, and the pipeline can
be altered in its behavior by changing an operator or expanded by adding
further operators."*
"""

from __future__ import annotations

from typing import Protocol

from . import engine, loader
from .model import Context, GenConfig


class GPO(Protocol):
    name: str

    def run(self, ctx: Context) -> Context: ...


class GenerationError(RuntimeError):
    def __init__(self, errors: list[str], warnings: list[str]):
        self.errors = errors
        self.warnings = warnings
        super().__init__(
            "TSLGen pipeline failed:\n" + "\n".join(f"  error: {e}" for e in errors)
        )


class TemplateCheckGPO:
    """Paper ①: 'every code template is loaded once into the framework and
    subsequently validated' — Jinja2 syntax errors surface here, not mid-render."""

    name = "template-check"

    def run(self, ctx: Context) -> Context:
        env = engine.environment()
        for name in env.list_templates(filter_func=lambda n: n.endswith(".j2")):
            try:
                env.get_template(name)
            except Exception as e:  # pragma: no cover - template bugs
                ctx.fail(f"template {name!r}: {e}")
        return ctx


class Pipeline:
    def __init__(self, operators: list[GPO]):
        self.operators = list(operators)

    def names(self) -> list[str]:
        return [op.name for op in self.operators]

    # exchangeability / extension port (paper Fig 5 ⑦)
    def append(self, op: GPO) -> "Pipeline":
        self.operators.append(op)
        return self

    def insert_after(self, name: str, op: GPO) -> "Pipeline":
        for i, existing in enumerate(self.operators):
            if existing.name == name:
                self.operators.insert(i + 1, op)
                return self
        raise KeyError(f"no GPO named {name!r}")

    def replace(self, name: str, op: GPO) -> "Pipeline":
        for i, existing in enumerate(self.operators):
            if existing.name == name:
                self.operators[i] = op
                return self
        raise KeyError(f"no GPO named {name!r}")

    def run(self, config: GenConfig, *, strict: bool = True) -> Context:
        ctx = Context(config=config)
        ctx.raw_targets = loader.load_raw_targets(config.upd_paths)
        ctx.raw_primitives = loader.load_raw_primitives(config.upd_paths)
        ctx.meta["fingerprint"] = loader.upd_fingerprint(config.upd_paths)
        for op in self.operators:
            ctx = op.run(ctx)
            if ctx.errors and strict:
                raise GenerationError(ctx.errors, ctx.warnings)
        return ctx


def core_pipeline(config: GenConfig) -> Pipeline:
    """The fundamental four-GPO core (paper ①) + configured extension GPOs."""
    from .benchgen import BenchSelectGPO
    from .buildgen import BuildGenGPO
    from .docgen import DocGenGPO
    from .generate import GenerateGPO
    from .select import SelectGPO
    from .testgen import TestGenGPO
    from .validate import ValidateGPO

    pipe = Pipeline([TemplateCheckGPO(), ValidateGPO(), SelectGPO(), GenerateGPO()])
    # extension port ⑦
    if config.use_bench_selection:
        pipe.insert_after("select", BenchSelectGPO())
    if config.emit_tests:
        pipe.append(TestGenGPO())
    if config.emit_build:
        pipe.append(BuildGenGPO())
    if config.emit_docs:
        pipe.append(DocGenGPO())
    return pipe
