"""Paper Fig 10: relative runtime of the generated-TSL range-count vs the
hand-written implementation (paper: generated within [-0.3%, +0.6%] of
Highway; popcount flavour within [-1%, +1.8%]).

Here both sides trace to XLA, so parity is the expected result — the point is
that the GENERATED abstraction adds zero runtime overhead, which is the
paper's claim. 4 GiB of data (paper's size) is scaled to 256 MiB to keep the
harness fast; the comparison is relative, so size cancels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import load_library

from .common import emit, time_fn

N = 1 << 26        # 64M float32 = 256 MiB


def _handwritten(data, lo, hi):
    m = jnp.logical_and(data >= lo, data <= hi)
    return jnp.sum(m.astype(jnp.int32))


def _handwritten_popcnt(data, lo, hi):
    flat = data.reshape(-1, 32)
    m = jnp.logical_and(flat >= lo, flat <= hi)
    w = jnp.left_shift(jnp.uint32(1), jnp.arange(32, dtype=jnp.uint32))
    packed = jnp.sum(m.astype(jnp.uint32) * w, axis=-1, dtype=jnp.uint32)
    return jnp.sum(jax.lax.population_count(packed).astype(jnp.int32))


def run() -> list[str]:
    lib = load_library("cpu_xla")
    rng = np.random.default_rng(0)
    data = jnp.asarray(rng.uniform(0, 100_000, N), jnp.float32)
    lo, hi = 5.0, 15.0

    hand = jax.jit(_handwritten)
    gen = jax.jit(lambda d: lib.ops.range_count(d, lo, hi))
    hand_pc = jax.jit(_handwritten_popcnt)
    gen_pc = jax.jit(lambda d: lib.ops.range_count_popcnt(d, lo, hi))

    assert int(hand(data, lo, hi)) == int(gen(data))
    assert int(hand_pc(data, lo, hi)) == int(gen_pc(data))

    t_hand = time_fn(hand, data, lo, hi, n_iter=10)
    t_gen = time_fn(gen, data, n_iter=10)
    t_hand_pc = time_fn(hand_pc, data, lo, hi, n_iter=10)
    t_gen_pc = time_fn(gen_pc, data, n_iter=10)

    rel = (t_gen - t_hand) / t_hand * 100
    rel_pc = (t_gen_pc - t_hand_pc) / t_hand_pc * 100
    gib_s = (N * 4 / 2**30) / (t_gen / 1e6)
    out = []
    emit("fig10_range_count_handwritten", t_hand, f"{gib_s:.1f}GiB/s_ref")
    emit("fig10_range_count_generated", t_gen,
         f"relative_delta={rel:+.2f}% (paper: -0.3..+0.6%)")
    emit("fig10_popcnt_handwritten", t_hand_pc, "")
    emit("fig10_popcnt_generated", t_gen_pc,
         f"relative_delta={rel_pc:+.2f}% (paper: -1..+1.8%)")
    out.append(f"range_count delta {rel:+.2f}%")
    out.append(f"popcnt delta {rel_pc:+.2f}%")
    return out


if __name__ == "__main__":
    run()
