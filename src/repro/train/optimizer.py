"""AdamW with optional int8-quantized moments (distributed-optimization trick:
8-bit optimizer state cuts the per-chip HBM for arctic-480b train from
~18.8 GB to ~8.4 GB — the difference between not fitting and fitting v5e;
EXPERIMENTS.md §Dry-run quantifies this).

No optax dependency — the optimizer is part of the substrate we must build.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"      # "float32" | "int8"


def lr_at(cfg: OptConfig, step):
    """Linear warmup + cosine decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


# -- int8 moment quantization (per-row absmax scaling) ------------------------

def _quantize(x):
    ax = -1 if x.ndim >= 1 else None
    amax = jnp.max(jnp.abs(x), axis=ax, keepdims=True) if x.ndim >= 1 else jnp.abs(x)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequantize(qs):
    return qs["q"].astype(jnp.float32) * qs["scale"]


def init_opt_state(cfg: OptConfig, params):
    def zeros(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.moment_dtype == "int8":
            return _quantize(z)
        return z
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(cfg: OptConfig, params, grads, opt_state):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, opt_state["count"])
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)
    quant = cfg.moment_dtype == "int8"

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _dequantize(m) if quant else m
        v_f = _dequantize(v) if quant else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
        update = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        if p.ndim >= 2:                       # decoupled wd on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return new_p, (_quantize(m_f) if quant else m_f), \
            (_quantize(v_f) if quant else v_f)

    is_q = lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
