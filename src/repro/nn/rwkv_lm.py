"""RWKV6 LM (Finch): attention-free stack of time-mix + channel-mix blocks.

Token-shift previous-token states are stored in NORMED space (the value that
token_shift actually mixes), so forward-collected states and decode-carried
states agree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.tsl_api import ops as tsl

from repro.nn import flags as _nn_flags


def _scan(f, init, xs, **kw):
    return jax.lax.scan(f, init, xs, unroll=_nn_flags.scan_unroll(), **kw)


from .common import apply_norm_params, dense_init, embed_init, init_norm, split_keys
from .lm import lm_head
from .rwkv6 import (channel_mix_forward, dims as r6_dims, init_rwkv6,
                    time_mix_decode, time_mix_forward)


def _init_block(key, cfg, dtype):
    return {
        "ln1": init_norm(cfg, dtype),
        "ln2": init_norm(cfg, dtype),
        "mix": init_rwkv6(key, cfg, dtype),
    }


def init_rwkv_lm(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ks = split_keys(key, 4)
    bkeys = jnp.stack(split_keys(ks[0], cfg.n_layers))
    return {
        "embed": embed_init(ks[1], (cfg.padded_vocab, cfg.d_model), dtype),
        "ln_in": init_norm(cfg, dtype),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg, dtype))(bkeys),
        "final_norm": init_norm(cfg, dtype),
        "head": dense_init(ks[2], (cfg.d_model, cfg.padded_vocab), dtype),
    }


def rwkv_forward(params, tokens, cfg, *, remat: bool = True,
                 collect_state: bool = False, state=None,
                 last_only: bool = False, n_real=None):
    """tokens (B,S) -> (logits, aux=0, states|None).

    ``n_real`` (scalar, may be traced): positions >= n_real are padding —
    the recurrent updates skip them exactly (chunked continuation prefill of
    a bucket-padded prompt), and collected states are those after the last
    REAL token. Pad logits rows are garbage the caller discards."""
    x = tsl.embed_lookup(params["embed"], tokens)
    x = apply_norm_params(cfg, params["ln_in"], x)
    if state is None:
        state = {"tm_prev": None, "cm_prev": None, "s": None}

    def body(x, inp):
        bp, tm_prev, cm_prev, s0 = inp
        xin = apply_norm_params(cfg, bp["ln1"], x)
        y, (tm_last, s_final) = time_mix_forward(bp["mix"], xin, cfg,
                                                 prev_tok=tm_prev, s0=s0,
                                                 n_real=n_real)
        x = x + y
        xin2 = apply_norm_params(cfg, bp["ln2"], x)
        y, cm_last = channel_mix_forward(bp["mix"], xin2, cfg,
                                         prev_tok=cm_prev, n_real=n_real)
        out = (tm_last, cm_last, s_final) if collect_state else None
        from repro.dist.sharding import logical_constraint
        return logical_constraint(x + y, "batch", None, None), out

    xs = (params["blocks"], state["tm_prev"], state["cm_prev"], state["s"])
    if state["tm_prev"] is None:
        # no incoming state: scan only over block params
        def body0(x, bp):
            return body(x, (bp, None, None, None))
        b = jax.checkpoint(body0, prevent_cse=False) if remat else body0
        x, outs = _scan(b, x, params["blocks"])
    else:
        b = jax.checkpoint(body, prevent_cse=False) if remat else body
        x, outs = _scan(b, x, xs)
    if last_only:
        x = x[:, -1:]
    x = apply_norm_params(cfg, params["final_norm"], x)
    logits = lm_head(params, x, cfg)
    if collect_state:
        tm, cm, s = outs
        return logits, jnp.float32(0), {"tm_prev": tm, "cm_prev": cm, "s": s}
    return logits, jnp.float32(0), None


def init_rwkv_state(cfg, batch: int, dtype):
    nh, hk = r6_dims(cfg)
    return {
        "tm_prev": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dtype),
        "cm_prev": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dtype),
        "s": jnp.zeros((cfg.n_layers, batch, nh, hk, hk), jnp.float32),
    }


def state_batch_axes(state):
    """Slot-axis position per state leaf (serve-layer state surgery): every
    recurrent leaf is (L, B, ...) — the request axis sits at 1."""
    return {k: 1 for k in state}


def state_page_axes(state):
    """Token-axis per leaf for PAGED serving: rwkv state is pure recurrence
    — no leaf grows with the sequence, so nothing pages (all ``None``). The
    paged store still buys rwkv residency accounting (tail bytes per
    request) and prefix sharing (a tail snapshot at a chunk boundary is the
    whole prefix state)."""
    return {k: None for k in state}


def rwkv_prefill_chunk(params, state, tokens, cfg, *, n_real=None):
    """Continuation prefill of one chunk: consume ``tokens`` (B,C) into the
    carried recurrent state (zeros == fresh start). Returns (logits (B,C,V),
    new state). Position-free: the serve-layer pos/kv_len args don't apply."""
    logits, _, new_state = rwkv_forward(params, tokens, cfg, remat=False,
                                        collect_state=True, state=state,
                                        n_real=n_real)
    return logits, new_state


def rwkv_verify_step(params, state, tokens, cfg):
    """Speculative-decoding verify span, PURE scoring: tokens (B,SV) — each
    slot's pending token + drafted continuation — are scored against the
    carried recurrent state WITHOUT committing it. Scan states cannot be
    truncated, so rollback is a checkpoint: the incoming state is returned
    unchanged and the engine replays the accepted prefix through
    :func:`rwkv_prefill_chunk` with per-slot ``n_real`` (verify_commit).
    Causality of the recurrence makes logits row j independent of rows > j
    (the accepted-prefix contract). Returns (logits (B,SV,V), state)."""
    logits, _, _ = rwkv_forward(params, tokens, cfg, remat=False,
                                collect_state=False, state=state)
    return logits, state


def rwkv_decode_step(params, state, tokens_t, pos, cfg):
    x = tsl.embed_lookup(params["embed"], tokens_t)
    x = apply_norm_params(cfg, params["ln_in"], x)

    def body(x_t, inp):
        bp, tm_prev, cm_prev, s = inp
        xin = apply_norm_params(cfg, bp["ln1"], x_t)
        y, tm_new, s = time_mix_decode(bp["mix"], xin, cfg, tm_prev, s)
        x_t = x_t + y
        xin2 = apply_norm_params(cfg, bp["ln2"], x_t)
        y, cm_new = channel_mix_forward(bp["mix"], xin2, cfg, prev_tok=cm_prev)
        return x_t + y, (tm_new, cm_new, s)

    x, (tm, cm, s) = _scan(
        body, x, (params["blocks"], state["tm_prev"], state["cm_prev"],
                  state["s"]))
    x = apply_norm_params(cfg, params["final_norm"], x)
    return lm_head(params, x, cfg)[:, 0], {"tm_prev": tm, "cm_prev": cm, "s": s}
