"""Model facade: family dispatch + loss + input specs for the dry-run.

build_model(cfg) returns a Model with a uniform surface:
    init(key) -> params
    loss(params, batch) -> (scalar, metrics)
    forward_logits(params, batch) -> logits
    prefill(params, batch, max_len) -> (last_logits, state)
    prefill_chunk(params, state, tokens, pos, kv_len, *,
                  n_real=None, embeds=None) -> (logits (B,C',V), state)
        (continuation prefill: consume one chunk of C prompt tokens into an
         existing decode state whose caches hold ``kv_len`` real rows; the
         chunk's K/V land at [pos, pos+C). ``n_real`` marks trailing padding
         rows whose state updates are skipped exactly — callers read logits
         at their last real row. ``embeds`` rides the FIRST chunk only: vlm
         vision prefix rows (C' = prefix + C) / audio encoder frames.
         Running every chunk then one decode step per generated token is
         token-for-token identical to whole-prompt ``prefill``.)
    decode_step(params, state, tokens_t, pos) -> (logits, state)
        (pos: scalar, or a (B,) vector of per-slot positions — continuous
         batching; recurrent families ignore it, attention caches scatter
         per-slot)
    verify_step(params, state, tokens, pos) -> (logits (B,SV,V), state)
        (speculative decoding: score a span of SV = k+1 tokens per slot —
         the pending token + k drafts — in ONE ragged batched step; logits
         row j validates draft j+1, row j is independent of rows > j. KV
         families write the span's cache slab and return the updated state:
         rollback is FREE, the accepted fill just stops short of rejected
         rows (kv_len truncation). Recurrent/hybrid families return the
         incoming state UNCHANGED — a checkpoint.)
    verify_commit(params, state, tokens, pos, n_commit) -> state  |  None
        (recurrent/hybrid only — None for KV families whose verify_step
         already committed: replay the accepted prefix of the span through
         the chunked-prefill path with per-slot ``n_commit`` (B,) real
         rows; n_commit == 0 is an exact identity for that slot, so
         rejected-slot rollback never perturbs neighbor slots.)
    init_decode_state(batch, max_len) -> zeroed state pytree
    state_batch_axes(state) -> pytree of slot-axis ints (same treedef)
    state_page_axes(state) -> dict of token-axis ints or None (same keys)
        (paged serving contract: each family declares which state leaves
         grow one row per cache token — those page through the
         cache_page_read/write primitives — and which are fixed-size
         per-request TAIL state (None) that the paged store snapshots
         whole: lm/vlm KV -> all paged; zamba -> attn KV paged, SSM/conv
         tails; rwkv -> all tails; encdec -> self-KV paged, cross-KV tails)
    insert_slot(state, donor, slot) / reset_slot(state, slot)
        (serve-layer state surgery: graft a freshly prefilled request into
         one slot of a live batched decode state / clear a finished slot —
         uniform over all four decode families via state_batch_axes)
    input_specs(cell) -> dict[str, ShapeDtypeStruct-compatible jnp dtypes]
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.arch import ArchConfig
from repro.configs.shapes import ShapeCell
from repro.tsl_api import ops as tsl

from . import encdec, lm, rwkv_lm, zamba


def _xent_loss(logits, labels, n_prefix: int = 0):
    if n_prefix:
        logits = logits[:, n_prefix:]
    per_tok = tsl.cross_entropy(logits, labels)
    return jnp.mean(per_tok)


@dataclass
class Model:
    cfg: ArchConfig
    init: Callable
    _forward: Callable           # (params, batch, remat) -> (logits, aux, _)
    prefill: Callable            # (params, batch, max_len) -> (logits, state)
    # (params, state, tokens, pos, kv_len, n_real=, embeds=) -> (logits, state)
    prefill_chunk: Callable
    decode_step: Callable        # (params, state, tokens, pos) -> (logits, state)
    init_decode_state: Callable  # (batch, max_len, **kw) -> state
    state_batch_axes: Callable   # (state) -> pytree of slot-axis ints
    # speculative decoding (see module docstring):
    verify_step: Callable = None     # (params, state, tokens, pos) -> (logits, state)
    verify_commit: Callable = None   # (params, state, tokens, pos, n_commit) -> state
    # paged serving (see module docstring): (state) -> {leaf: tok-axis|None}
    state_page_axes: Callable = None
    # FUSED paged serving — decode/verify DIRECTLY against the block-table
    # page pools, no page->lane gather. ``state`` is the TAIL-only dict (the
    # state_page_axes None leaves, batched); ``pools`` the store's device
    # pool dict ({leaf} + optional {leaf}__scale int8 scales); ``tables``
    # (B, P) int32 page ids (scratch-page padded); ``pos`` (B,) per-slot.
    # None for families with no paged leaves (rwkv) — the engine falls back
    # to lane activation.
    decode_step_paged: Callable = None   # (p, st, pools, tables, t, pos) -> (logits, st, pools)
    verify_step_paged: Callable = None   # (p, st, pools, tables, t, pos) -> (logits, st, pools)
    # recurrent/hybrid only: replay the accepted prefix on the pools
    verify_commit_paged: Callable = None  # (p, st, pools, tables, t, pos, n) -> (st, pools)

    def forward_logits(self, params, batch, *, remat: bool = False):
        logits, _, _ = self._forward(params, batch, remat)
        return logits

    # -- state surgery (continuous batching: repro.serve builds on these) ----

    def insert_slot(self, state, donor, slot):
        """Graft a single-request decode state (slot axis of size 1, e.g.
        straight from ``prefill`` with batch 1) into slot ``slot`` of a live
        batched state. jit-safe: ``slot`` may be traced."""
        def ins(leaf, d, ax):
            return jax.lax.dynamic_update_slice_in_dim(
                leaf, d.astype(leaf.dtype), slot, axis=ax)

        return jax.tree.map(ins, state, donor, self.state_batch_axes(state))

    def reset_slot(self, state, slot):
        """Zero slot ``slot`` (request finished / evicted). jit-safe."""
        def rst(leaf, ax):
            shape = list(leaf.shape)
            shape[ax] = 1
            return jax.lax.dynamic_update_slice_in_dim(
                leaf, jnp.zeros(shape, leaf.dtype), slot, axis=ax)

        return jax.tree.map(rst, state, self.state_batch_axes(state))

    def loss(self, params, batch, *, remat: bool = True):
        logits, aux, _ = self._forward(params, batch, remat)
        n_prefix = self.cfg.vision_prefix if self.cfg.family == "vlm" else 0
        ce = _xent_loss(logits, batch["labels"], n_prefix)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    # -- dry-run input specs (ShapeDtypeStruct stand-ins, no allocation) -----

    def input_specs(self, cell: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        tok = jnp.int32
        emb = jnp.dtype(cfg.dtype)
        if cell.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, S), tok),
                "labels": jax.ShapeDtypeStruct((B, S), tok),
            }
            if cfg.family == "vlm":
                specs["vision_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.vision_prefix, cfg.d_model), emb)
            if cfg.family == "audio":
                specs["audio_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), emb)
            return specs
        if cell.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
            if cfg.family == "vlm":
                specs["vision_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.vision_prefix, cfg.d_model), emb)
            if cfg.family == "audio":
                specs["audio_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), emb)
            return specs
        # decode: one token + the state pytree (KV cache of seq_len)
        state = jax.eval_shape(lambda: self.init_decode_state(B, S))
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), tok),
            "state": state,
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }


def build_model(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def fwd(params, batch, remat):
            return lm.lm_forward(params, batch["tokens"], cfg,
                                 vision_embeds=batch.get("vision_embeds"),
                                 remat=remat)

        return Model(
            cfg=cfg,
            init=lambda key: lm.init_lm(key, cfg),
            _forward=fwd,
            prefill=lambda p, batch, max_len: lm.lm_prefill(
                p, batch["tokens"], cfg, max_len=max_len,
                vision_embeds=batch.get("vision_embeds")),
            prefill_chunk=lambda p, st, t, pos, kv_len, n_real=None,
                embeds=None: lm.lm_prefill_chunk(
                    p, st, t, pos, cfg, vision_embeds=embeds),
            decode_step=lambda p, st, t, pos: lm.lm_decode_step(p, st, t, pos, cfg),
            init_decode_state=lambda b, s, **kw: lm.init_decode_state(
                cfg, b, s, jnp.dtype(cfg.dtype)),
            state_batch_axes=lm.state_batch_axes,
            state_page_axes=lm.state_page_axes,
            verify_step=lambda p, st, t, pos: lm.lm_verify_step(
                p, st, t, pos, cfg),
            decode_step_paged=lambda p, st, pools, tab, t, pos:
                lm.lm_decode_step_paged(p, st, pools, tab, t, pos, cfg),
            verify_step_paged=lambda p, st, pools, tab, t, pos:
                lm.lm_verify_step_paged(p, st, pools, tab, t, pos, cfg),
        )
    if fam == "hybrid":
        def fwd(params, batch, remat):
            return zamba.zamba_forward(params, batch["tokens"], cfg, remat=remat)

        return Model(
            cfg=cfg,
            init=lambda key: zamba.init_zamba(key, cfg),
            _forward=fwd,
            prefill=lambda p, batch, max_len: zamba.zamba_prefill(
                p, batch["tokens"], cfg, max_len=max_len),
            prefill_chunk=lambda p, st, t, pos, kv_len, n_real=None,
                embeds=None: zamba.zamba_prefill_chunk(
                    p, st, t, pos, cfg, n_real=n_real),
            decode_step=lambda p, st, t, pos: zamba.zamba_decode_step(
                p, st, t, pos, cfg),
            init_decode_state=lambda b, s, **kw: zamba.init_zamba_state(
                cfg, b, s, jnp.dtype(cfg.dtype)),
            state_batch_axes=zamba.state_batch_axes,
            state_page_axes=zamba.state_page_axes,
            verify_step=lambda p, st, t, pos: zamba.zamba_verify_step(
                p, st, t, pos, cfg),
            verify_commit=lambda p, st, t, pos, n: zamba.zamba_prefill_chunk(
                p, st, t, pos, cfg, n_real=n)[1],
            decode_step_paged=lambda p, st, pools, tab, t, pos:
                zamba.zamba_decode_step_paged(p, st, pools, tab, t, pos, cfg),
            verify_step_paged=lambda p, st, pools, tab, t, pos:
                zamba.zamba_verify_step_paged(p, st, pools, tab, t, pos, cfg),
            verify_commit_paged=lambda p, st, pools, tab, t, pos, n:
                zamba.zamba_verify_commit_paged(p, st, pools, tab, t, pos,
                                                cfg, n),
        )
    if fam == "ssm":
        def fwd(params, batch, remat):
            return rwkv_lm.rwkv_forward(params, batch["tokens"], cfg, remat=remat)

        return Model(
            cfg=cfg,
            init=lambda key: rwkv_lm.init_rwkv_lm(key, cfg),
            _forward=fwd,
            prefill=lambda p, batch, max_len: rwkv_prefill(p, batch, cfg),
            prefill_chunk=lambda p, st, t, pos, kv_len, n_real=None,
                embeds=None: rwkv_lm.rwkv_prefill_chunk(
                    p, st, t, cfg, n_real=n_real),
            decode_step=lambda p, st, t, pos: rwkv_lm.rwkv_decode_step(
                p, st, t, pos, cfg),
            init_decode_state=lambda b, s, **kw: rwkv_lm.init_rwkv_state(
                cfg, b, jnp.dtype(cfg.dtype)),
            state_batch_axes=rwkv_lm.state_batch_axes,
            state_page_axes=rwkv_lm.state_page_axes,
            verify_step=lambda p, st, t, pos: rwkv_lm.rwkv_verify_step(
                p, st, t, cfg),
            verify_commit=lambda p, st, t, pos, n: rwkv_lm.rwkv_prefill_chunk(
                p, st, t, cfg, n_real=n)[1],
        )
    if fam == "audio":
        def fwd(params, batch, remat):
            return encdec.encdec_forward(params, batch["tokens"], cfg,
                                         audio_embeds=batch["audio_embeds"],
                                         remat=remat)

        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            _forward=fwd,
            prefill=lambda p, batch, max_len: encdec.encdec_prefill(
                p, batch["tokens"], cfg, audio_embeds=batch["audio_embeds"],
                max_len=max_len),
            prefill_chunk=lambda p, st, t, pos, kv_len, n_real=None,
                embeds=None: encdec.encdec_prefill_chunk(
                    p, st, t, pos, cfg, audio_embeds=embeds),
            decode_step=lambda p, st, t, pos: encdec.encdec_decode_step(
                p, st, t, pos, cfg),
            # enc_len: serve engines size the per-request cross-state by the
            # (fixed) encoder length, not max_len (dry-run default keeps s)
            init_decode_state=lambda b, s, enc_len=None, **kw:
                encdec.init_encdec_state(
                    cfg, b, s, enc_len=s if enc_len is None else enc_len,
                    dtype=jnp.dtype(cfg.dtype)),
            state_batch_axes=encdec.state_batch_axes,
            state_page_axes=encdec.state_page_axes,
            verify_step=lambda p, st, t, pos: encdec.encdec_verify_step(
                p, st, t, pos, cfg),
            decode_step_paged=lambda p, st, pools, tab, t, pos:
                encdec.encdec_decode_step_paged(p, st, pools, tab, t, pos,
                                                cfg),
            verify_step_paged=lambda p, st, pools, tab, t, pos:
                encdec.encdec_verify_step_paged(p, st, pools, tab, t, pos,
                                                cfg),
        )
    raise ValueError(f"unknown family {fam!r}")


def rwkv_prefill(params, batch, cfg):
    logits, _, state = rwkv_lm.rwkv_forward(params, batch["tokens"], cfg,
                                            remat=False, collect_state=True,
                                            last_only=True)
    return logits[:, -1], state
