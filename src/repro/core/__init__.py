"""TSLGen-JAX — the paper's generator framework (DESIGN.md §1/§3).

Public surface:
    load_library(target=...)   -> generated + imported TSL module
    generate_library(config)   -> on-disk package
    GenConfig, Pipeline, core_pipeline — for custom pipelines (extension port)
"""

from .library import generate_library, load_library
from .model import Context, GenConfig
from .pipeline import GenerationError, Pipeline, core_pipeline

__all__ = [
    "load_library",
    "generate_library",
    "GenConfig",
    "Context",
    "Pipeline",
    "core_pipeline",
    "GenerationError",
]
