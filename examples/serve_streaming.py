"""Streaming serving demo: a fixed-seed Poisson arrival trace driven through
the chunked-prefill slot engine.

Requests become visible to admission only at their arrival times (async
ingestion), prompts are padded to UPD-declared length buckets, and prefill
advances one fixed-size chunk per unified step ALONGSIDE decode — so the
deliberately long prompt arriving mid-run (4x the smallest bucket) never
stalls token generation for the requests already running. The report's
per-step log proves it: every step that ran one of the long prompt's chunks
also decoded a token for each running slot.

    PYTHONPATH=src python examples/serve_streaming.py
"""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.serve import Request, SamplingConfig, ServeEngine  # noqa: E402

ARCH = "qwen1.5-0.5b"
BATCH = 3
RATE_HZ = 40.0          # Poisson arrival rate (reduced models decode ~ms/step)
N_REQUESTS = 8
LONG_PROMPT = 32        # 4x the smallest bucket (8)


def build_trace(cfg, seed: int = 0) -> list[Request]:
    """Fixed-seed Poisson arrivals with mixed prompt/gen lengths; request 4
    is the long one (bucket 32) landing mid-run."""
    rng = np.random.default_rng(seed)
    t = 0.0
    requests = []
    for i in range(N_REQUESTS):
        t += float(rng.exponential(1.0 / RATE_HZ))
        p = LONG_PROMPT if i == 4 else int(rng.choice([5, 8, 13]))
        g = int(rng.integers(6, 14))
        requests.append(Request(
            rid=f"req{i}",
            tokens=rng.integers(0, cfg.vocab, p).astype(np.int32),
            gen_len=g, sla_s=60.0, arrival_s=t))
    return requests


def main():
    cfg = get_config(ARCH).reduced()
    engine = ServeEngine(
        cfg, batch=BATCH, max_len=48,
        sampling=SamplingConfig(temperature=0.7, top_k=20), seed=0)
    requests = build_trace(cfg)
    long_rid = "req4"

    report = engine.run(requests)

    print(f"[example] {ARCH}: {report['requests']} served over "
          f"{report['steps']} unified steps "
          f"(buckets={report['buckets']}, chunk={report['prefill_chunk']})")
    print(f"[example]   ttft by bucket: "
          f"{json.dumps(report['ttft_by_bucket'])}")
    print(f"[example]   padded steady-state slot-steps: "
          f"{report['padded_slot_steps_steady']}")

    assert report["requests"] == N_REQUESTS, report["refused"]
    assert report["padded_slot_steps_steady"] == 0, report

    # the long prompt really arrived mid-run and really ran multiple chunks
    long_steps = [e for e in report["step_log"]
                  if long_rid in e["prefill_rids"]]
    assert len(long_steps) == 32 // report["prefill_chunk"], long_steps
    assert min(e["step"] for e in long_steps) > 0

    # NO DECODE STALL: every step that advanced the long prompt's prefill
    # also decoded one token for every already-running slot
    stalled = [e for e in long_steps if e["decoded"] == 0]
    assert not stalled, f"decode stalled during long-prompt prefill: {stalled}"
    print(f"[example]   long prompt {long_rid}: "
          f"{len(long_steps)} chunk steps, decode kept running in all of them")

    # TTFT percentiles split by bucket cover the long prompt's bucket
    assert 32 in report["ttft_by_bucket"], report["ttft_by_bucket"]

    # arrival gating: nobody's TTFT is measured from before their arrival
    per_req = {m["rid"]: m for m in report["per_request"]}
    for r in requests:
        assert per_req[r.rid]["ttft_s"] > 0
    print("[example] ok")


if __name__ == "__main__":
    main()
