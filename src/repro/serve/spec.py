"""Speculative decoding on the slot table: drafters, the acceptance rule,
and UPD-cost-priced depth selection.

The engine's speculative round is draft -> verify -> accept -> commit:

* a cheap **drafter** proposes up to k tokens per slot (n-gram/prompt-lookup
  over the slot's committed token history first; a small-config draft model
  from ``configs/registry.py`` as the second tier);
* ONE batched ragged **verify** step (``Model.verify_step`` over the
  ``attention_verify`` UPD primitive) scores every slot's span
  ``[pending, d_1 .. d_k]`` at its own ``(B,)`` position — logits row j
  validates draft j+1 and is independent of rows > j;
* the **acceptance rule** (:func:`accept_span`) keeps each slot's longest
  accepted prefix plus ONE corrected token from the first rejected row —
  with greedy sampling the emitted stream is token-for-token identical to
  plain decode; with sampled rows acceptance is exact-match against the
  sampled target token, which leaves the output distribution unchanged;
* **commit**: KV families already wrote the span's cache slab (rollback is
  kv_len truncation — free); recurrent families replay the accepted prefix
  through the chunked-prefill path (``Model.verify_commit``) from the
  checkpointed pre-verify state.

Speculation depth k is a PER-SLOT, PER-STEP decision priced by the UPD cost
channel (:class:`SpeculationPolicy`): expected emitted tokens from a
per-slot acceptance EMA vs drafter cost + the ``attention_verify`` bytes
term at span k+1 (doubled for recurrent families — the commit replay).
k = 0 degrades to today's decode step exactly. The span bound ``k_max`` is
UPD data (the ``serve:`` block on ``attention_verify`` in
``tsl_data/primitives/seq.yaml``), not an engine constant.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.launch.roofline import HBM_BW

# fallback when the UPD corpus is unavailable (mirrors the serve: block on
# the attention_verify primitive)
DEFAULT_K_MAX = 4


def upd_verify_defaults() -> dict:
    """The ``serve:`` block declared on the attention_verify primitive:
    {"k_max": int} — the largest drafted span the engine may propose per
    slot per step (verify width SV = k+1). Falls back to the module default
    if the corpus (or the block) is missing."""
    try:
        from repro.core import load_corpus

        extra = load_corpus().primitives["attention_verify"].extra
        return {"k_max": int(dict(extra["serve"])["k_max"])}
    except Exception:
        return {"k_max": DEFAULT_K_MAX}


def accept_span(drafts, target, window):
    """Longest-accepted-prefix acceptance rule (pure host function).

    ``drafts`` (B, K): the proposed continuation per slot.
    ``target`` (B, K+1): the target model's token at every span row —
    row j is what the target emits AFTER ``[pending, d_1..d_j]``, so
    ``target[:, j]`` validates ``drafts[:, j]``.
    ``window`` (B,): per-slot admissible draft count (<= K; slots near
    their gen_len budget or priced at a smaller depth get a smaller
    window — rows beyond it are never accepted).

    Returns ``m`` (B,): the number of leading drafts accepted per slot.
    The slot emits ``drafts[:m]`` plus the corrected token
    ``target[:, m]`` — m+1 tokens. m is by construction a PREFIX length:
    every accepted draft index j < m satisfies drafts[j] == target[j] and
    j < window."""
    drafts = np.asarray(drafts)
    target = np.asarray(target)
    b, k = drafts.shape
    if target.shape != (b, k + 1):
        raise ValueError(f"target must be (B, K+1)={(b, k + 1)}, "
                         f"got {target.shape}")
    window = np.minimum(np.asarray(window, np.int64), k)
    match = drafts == target[:, :k]
    m = np.cumprod(match, axis=1).sum(axis=1) if k else np.zeros(b, np.int64)
    return np.minimum(m, np.maximum(window, 0)).astype(np.int64)


@dataclass(frozen=True)
class SpeculationConfig:
    """Engine-facing speculation knobs.

    ``k_max`` None -> the UPD serve block on attention_verify.
    ``drafter`` "ngram" (host prompt-lookup, zero device cost) or
    "draft_model" (a small-config lm-family arch named by ``draft_arch``,
    run on its own slot table with the same chunk schedule).
    ``fixed_k`` pins the depth (tests); None -> cost-priced per slot.
    """

    k_max: int | None = None
    drafter: str = "ngram"
    draft_arch: str | None = None
    max_ngram: int = 3
    ema_decay: float = 0.75         # per-slot acceptance EMA smoothing
    ema_init: float = 0.5           # optimism prior for fresh slots
    fixed_k: int | None = None


class SpeculationPolicy:
    """Per-slot speculation depth priced by the UPD cost channel.

    For each candidate depth k the policy compares expected emitted tokens
    per second:  E(k, a) / T(k)  with a the slot's acceptance EMA,
    E(k, a) = (1 - a^(k+1)) / (1 - a) (expected accepted prefix + the
    corrected token under i.i.d. per-draft acceptance a) and
    T(k) = k * drafter_cost + verify_seconds(k) from
    ``CostModelAdmission.verify_seconds`` (the attention_verify bytes term
    at span k+1 over HBM_BW, doubled for recurrent families whose commit
    replays the span). k = 0 is always a candidate — priced at the plain
    decode step — so speculation degrades to today's decode exactly when
    the cost channel says drafting doesn't pay."""

    def __init__(self, batch: int, k_max: int, cost_model, spec_cfg,
                 drafter_cost_s: float = 0.0):
        self.k_max = int(k_max)
        self.cm = cost_model            # CostModelAdmission (host arithmetic)
        self.cfg = spec_cfg
        self.drafter_cost_s = float(drafter_cost_s)
        self.alpha = np.full(batch, float(spec_cfg.ema_init))

    def reset(self, slot: int) -> None:
        self.alpha[slot] = float(self.cfg.ema_init)

    def update(self, slot: int, proposed: int, accepted: int) -> None:
        """Fold one verify round's per-draft acceptance into the slot EMA."""
        if proposed <= 0:
            return
        d = float(self.cfg.ema_decay)
        self.alpha[slot] = d * self.alpha[slot] \
            + (1.0 - d) * (accepted / proposed)

    def expected_emitted(self, k: int, alpha: float) -> float:
        a = min(max(float(alpha), 0.0), 0.999)
        return (1.0 - a ** (k + 1)) / (1.0 - a)

    def depth(self, slot: int, fill: int, remaining: int) -> int:
        """Draft count for this slot this step; 0 -> plain decode. Clipped
        to ``remaining - 1`` so a round never emits past gen_len."""
        cap = min(self.k_max, max(int(remaining) - 1, 0))
        if cap <= 0:
            return 0
        if self.cfg.fixed_k is not None:
            return min(int(self.cfg.fixed_k), cap)
        s = int(fill) + 1
        best_k, best = 0, 1.0 / max(self.cm.step_seconds(s), 1e-30)
        a = self.alpha[slot]
        for k in range(1, cap + 1):
            t = k * self.drafter_cost_s + self.cm.verify_seconds(k, s)
            rate = self.expected_emitted(k, a) / max(t, 1e-30)
            if rate > best:
                best_k, best = k, rate
        return best_k


class NGramDrafter:
    """Tier-1 drafter: prompt-lookup / n-gram continuation, pure host.

    For each slot, match the longest suffix n-gram (n down to 1) of the
    committed token history against an earlier occurrence in the SAME
    history (prompt included — prompt-echo workloads hit here), and propose
    the k tokens that followed it; repeat-last-token fills any shortfall.
    Zero device cost: ``cost_per_token_s`` is 0, so the policy prices pure
    verify against expected acceptance."""

    def __init__(self, max_ngram: int = 3):
        self.max_ngram = int(max_ngram)

    def cost_per_token_s(self) -> float:
        return 0.0

    # engine lifecycle hooks (stateless drafter: all no-ops)
    def on_chunk(self, rid, seg, n_real) -> None:
        pass

    def on_graft(self, rid, slot, history) -> None:
        pass

    def on_commit(self, slot, m) -> None:
        pass

    def on_finish(self, slot) -> None:
        pass

    def _continue(self, hist: np.ndarray, k: int) -> list[int]:
        n_hist = len(hist)
        for n in range(min(self.max_ngram, n_hist - 1), 0, -1):
            suffix = hist[-n:]
            # rightmost earlier occurrence of the suffix n-gram
            for start in range(n_hist - n - 1, -1, -1):
                if np.array_equal(hist[start:start + n], suffix):
                    cont = hist[start + n:start + n + k]
                    if len(cont):
                        out = list(int(t) for t in cont)
                        while len(out) < k:
                            out.append(out[-1])
                        return out
        return [int(hist[-1])] * k

    def propose(self, active, histories, k_vec, batch: int,
                K: int) -> np.ndarray:
        """-> (batch, K) int drafts; rows of inactive slots are zeros."""
        drafts = np.zeros((batch, K), np.int64)
        for slot in active:
            if k_vec[slot] <= 0:
                continue
            hist = np.asarray(histories[slot], np.int64)
            drafts[slot, :] = self._continue(hist, K)
        return drafts


class DraftModelDrafter:
    """Tier-2 drafter: a small-config lm-family draft model running on its
    own slot table, kept in lockstep with the target's slot lifecycle.

    The draft state mirrors the target's chunk schedule (``on_chunk``
    advances a batch-1 draft donor with the same padded segments;
    ``on_graft`` grafts it into the draft slot table), then each
    ``propose`` round (1) catches the draft cache up to the committed
    history — token-by-token feeds; already-caught-up slots idempotently
    re-feed their last token at its own row — and (2) runs K greedy draft
    decode steps. Rows written for later-rejected drafts need no rollback:
    the next catch-up overwrites them (KV cache, kv_len-masked).

    The draft model must share the target's vocabulary (token ids are
    compared verbatim by the acceptance rule)."""

    def __init__(self, draft_cfg, target_cfg, *, batch: int, state_len: int,
                 seed: int = 0):
        import jax
        import jax.numpy as jnp

        from repro.nn.model import build_model

        if draft_cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"draft model {draft_cfg.name!r} must be a plain lm family "
                f"(dense/moe), got {draft_cfg.family!r}")
        if draft_cfg.vocab != target_cfg.vocab:
            raise ValueError(
                f"draft vocab {draft_cfg.vocab} != target vocab "
                f"{target_cfg.vocab}: acceptance compares token ids verbatim")
        self._jnp = jnp
        self.cfg = draft_cfg
        self.batch = batch
        self.state_len = int(state_len)
        self.model = build_model(draft_cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.state = self.model.init_decode_state(batch, self.state_len)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._chunk = jax.jit(self.model.prefill_chunk, donate_argnums=(1,),
                              static_argnums=())
        self._insert = jax.jit(self.model.insert_slot, donate_argnums=(0,))
        # tokens of the committed history already fed into the draft cache
        self.consumed = np.zeros(batch, np.int64)
        self._len_before = np.zeros(batch, np.int64)
        self._last_K = 0
        self._donors: dict[str, tuple[object, int]] = {}
        self._cost_model = None

    def cost_per_token_s(self) -> float:
        """One draft decode step on the roofline (memory-bound), from the
        same cost channel the target's admission prices with."""
        if self._cost_model is None:
            from .scheduler import CostModelAdmission

            self._cost_model = CostModelAdmission(
                self.cfg, self.batch, self.state_len)
        return self._cost_model.step_seconds()

    # -- target-lifecycle mirror ---------------------------------------------

    def on_chunk(self, rid, seg, n_real) -> None:
        """Advance this request's draft donor by the SAME padded chunk the
        target prefilled (draft positions carry no vision/audio prefix)."""
        jnp = self._jnp
        if rid not in self._donors:
            self._donors[rid] = (
                self.model.init_decode_state(1, self.state_len), 0)
        donor, fill = self._donors[rid]
        _, donor = self._chunk(self.params, donor, jnp.asarray(seg, jnp.int32),
                               jnp.int32(fill), jnp.int32(fill))
        self._donors[rid] = (donor, fill + int(n_real))

    def on_graft(self, rid, slot, history) -> None:
        donor, fill = self._donors.pop(rid)
        self.state = self._insert(self.state, donor, slot)
        # the target's first sampled token is in `history` but has not been
        # fed to the draft yet — catch-up handles it next propose round
        self.consumed[slot] = fill

    def on_commit(self, slot, m) -> None:
        """After a verify round accepting m drafts: draft rows are correct
        through the old history plus the first min(m, K-1) drafts it fed
        while proposing (draft K itself was proposed but never fed)."""
        self.consumed[slot] = self._len_before[slot] \
            + min(int(m), max(self._last_K - 1, 0))

    def on_finish(self, slot) -> None:
        self.consumed[slot] = 0

    # -- the draft rounds ------------------------------------------------------

    def _feed(self, tok_vec: np.ndarray, pos_vec: np.ndarray):
        jnp = self._jnp
        logits, self.state = self._decode(
            self.params, self.state, jnp.asarray(tok_vec[:, None], jnp.int32),
            jnp.asarray(pos_vec, jnp.int32))
        return np.asarray(logits)[..., :self.cfg.vocab]

    def propose(self, active, histories, k_vec, batch: int,
                K: int) -> np.ndarray:
        lens = np.zeros(batch, np.int64)
        for slot in active:
            lens[slot] = len(histories[slot])
            self._len_before[slot] = lens[slot]
        self._last_K = K
        # phase 1: catch up to the committed history (all but its last
        # token); caught-up slots re-feed their newest fed token at its own
        # row — an idempotent rewrite, logits discarded
        lag = max((int(lens[s]) - 1 - int(self.consumed[s]) for s in active),
                  default=0)
        for _ in range(max(lag, 0)):
            toks = np.zeros(batch, np.int64)
            pos = np.maximum(self.consumed - 1, 0)
            for slot in active:
                c = int(self.consumed[slot])
                if c < lens[slot] - 1:
                    toks[slot] = histories[slot][c]
                    pos[slot] = c
                    self.consumed[slot] = c + 1
                elif c > 0:
                    toks[slot] = histories[slot][c - 1]
                    pos[slot] = c - 1
            self._feed(toks, pos)
        # phase 2: K greedy draft steps from each slot's pending token
        drafts = np.zeros((batch, K), np.int64)
        cur = np.zeros(batch, np.int64)
        pos = np.maximum(self.consumed - 1, 0)
        for slot in active:
            cur[slot] = histories[slot][-1]
            pos[slot] = lens[slot] - 1
        for i in range(K):
            logits = self._feed(cur, pos)
            cur = logits.argmax(-1).astype(np.int64)
            pos = pos + 1
            drafts[:, i] = cur
        return drafts


def build_drafter(spec_cfg: SpeculationConfig, target_cfg, *, batch: int,
                  state_len: int, seed: int = 0):
    if spec_cfg.drafter == "ngram":
        return NGramDrafter(max_ngram=spec_cfg.max_ngram)
    if spec_cfg.drafter == "draft_model":
        if not spec_cfg.draft_arch:
            raise ValueError("drafter='draft_model' needs draft_arch "
                             "(a configs/registry.py name)")
        from repro.configs.registry import get_config

        draft_cfg = get_config(spec_cfg.draft_arch)
        if target_cfg.vocab != draft_cfg.vocab:
            # reduced() test configs shrink vocab — mirror the reduction so
            # registry pairs stay usable in both full and reduced runs
            draft_cfg = draft_cfg.reduced()
        return DraftModelDrafter(draft_cfg, target_cfg, batch=batch,
                                 state_len=state_len, seed=seed)
    raise ValueError(f"unknown drafter {spec_cfg.drafter!r}")


__all__ = [
    "DEFAULT_K_MAX",
    "DraftModelDrafter",
    "NGramDrafter",
    "SpeculationConfig",
    "SpeculationPolicy",
    "accept_span",
    "build_drafter",
    "upd_verify_defaults",
]
