"""Data model for the TSLGen-JAX generator (paper §3.1/§3.2 ⑤).

The paper's UPD ("user provided data") consists of two document families:

* **SRUs** ("SISE representation units", here: hardware-target representation
  units) — one YAML document per execution target (``tsl_data/targets/*.yaml``).
* **Primitives** — one YAML document per primitive, each carrying one or more
  *definitions* (per-target implementations guarded by required feature flags,
  the analogue of the paper's ``lscpu_flags``), plus optional *tests* consumed
  by the test-generation GPO (paper §4.1).

These dataclasses are produced by the validation GPO after schema
checking/enrichment; downstream GPOs operate only on these types.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from types import MappingProxyType
from typing import Any, Mapping


@dataclass(frozen=True)
class TargetDef:
    """An SRU: everything the generator knows about one execution target.

    The paper's SRU captures register/mask types and register width; the
    TPU-native analogue captures tile geometry (sublane × lane), MXU shape,
    VMEM budget and roofline constants (DESIGN.md §2).
    """

    name: str
    vendor: str
    flags: tuple[str, ...]              # provided feature flags (lscpu_flags analogue)
    ctypes: tuple[str, ...]             # supported element types
    default_ctype: str
    lanes: int                          # VREG lane count
    sublanes: int                       # VREG sublane count
    mxu: tuple[int, int]                # systolic array shape
    vmem_bytes: int
    hbm_bytes: int
    peak_flops_bf16: float              # per-chip peak, FLOP/s
    hbm_bw: float                       # bytes/s
    ici_bw: float                       # bytes/s per link
    ici_links: int
    interpret: bool = False             # Pallas interpret-mode target?
    runs_on_host: bool = True           # can impls execute in this process?
    dtype_map: dict[str, str] = field(default_factory=dict)   # ctype -> short name (paper: Neon naming scheme)
    description: str = ""
    extra: dict[str, Any] = field(default_factory=dict)       # schema allows arbitrary extra fields

    def as_render_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d.update(d.pop("extra"))
        return d


@dataclass(frozen=True)
class ParamDef:
    name: str
    ctype: str = "register"             # semantic type tag (register/mask/scalar/shape/...)
    default: str | None = None          # python literal source or None (positional)
    attributes: tuple[str, ...] = ()    # e.g. ("keyword_only",)
    description: str = ""


@dataclass(frozen=True)
class ImplDef:
    """One per-target implementation of a primitive (paper Fig 6a ``definitions``)."""

    target_extension: str
    ctypes: tuple[str, ...]
    flags: tuple[str, ...]              # required feature flags (paper: lscpu_flags)
    implementation: str                 # python function body (Jinja2-renderable, stage-1)
    is_native: bool = True              # paper §3.2: maps directly to hw capability?
    helpers: str = ""                   # module-level code rendered once (imports, defs)
    cost: dict[str, str] = field(default_factory=dict)  # beyond-paper: flops/bytes formulas
    note: str = ""
    lint: dict[str, Any] = field(default_factory=dict)  # {"suppress": ["TSL0xx", ...]}

    @property
    def loc(self) -> int:
        """Lines of code — the paper's tie-breaker in the selection heuristic."""
        return sum(1 for ln in self.implementation.splitlines() if ln.strip())


@dataclass(frozen=True)
class TestDef:
    """A test case co-located with the primitive (paper §4.1)."""

    __test__ = False                    # not a pytest class, despite the name

    name: str
    implementation: str
    requires: tuple[str, ...] = ()      # primitive dependencies -> test DAG edges


@dataclass(frozen=True)
class PrimitiveDef:
    name: str
    group: str                          # output module grouping (calc/mask/reduce/nn/...)
    brief: str
    parameters: tuple[ParamDef, ...]
    returns_ctype: str
    definitions: tuple[ImplDef, ...]
    tests: tuple[TestDef, ...] = ()
    dispatch: str = "auto"              # "auto" | "none" | parameter name
    bench: dict[str, Any] | None = None  # sample-input factory for benchgen
    cost_shapes: tuple[str, ...] = ()   # shape symbols cost: formulas may use
    lint: dict[str, Any] = field(default_factory=dict)  # {"suppress": ["TSL0xx", ...]}
    extra: dict[str, Any] = field(default_factory=dict)

    def dispatch_param(self) -> str | None:
        """Name of the parameter whose dtype drives specialization dispatch."""
        if self.dispatch == "none":
            return None
        if self.dispatch != "auto":
            return self.dispatch
        for p in self.parameters:
            if p.ctype in ("register", "mask"):
                return p.name
        return None

    def signature(self) -> str:
        """Python signature source for the generated public function."""
        parts: list[str] = []
        kw_started = False
        for p in self.parameters:
            kw = "keyword_only" in p.attributes
            if kw and not kw_started:
                parts.append("*")
                kw_started = True
            parts.append(p.name if p.default is None else f"{p.name}={p.default}")
        return ", ".join(parts)

    def arg_names(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.parameters)


@dataclass
class Selection:
    """Result of the selection GPO for one (target, primitive, ctype)."""

    primitive: str
    target: str
    ctype: str
    impl: ImplDef
    score: int                          # number of matched required flags
    candidates: int                     # how many implementations were valid
    reason: str = ""                    # human-readable provenance ("flags", "bench", ...)


@dataclass
class GeneratedFile:
    relpath: str
    content: str
    kind: str = "code"                  # code | test | build | doc


@dataclass
class CorpusBuild:
    """Mutable state flowing through the *corpus* pipeline (load → validate).

    Target-agnostic: loading, template checking, schema validation and
    enrichment happen once per UPD fingerprint, not once per generation
    target.  ``freeze()`` produces the immutable :class:`CorpusIR` every
    per-target pipeline run shares.
    """

    upd_paths: tuple[str, ...] = ()
    fingerprint: str = ""
    raw_targets: list[dict] = field(default_factory=list)
    raw_primitives: list[dict] = field(default_factory=list)
    targets: dict[str, TargetDef] = field(default_factory=dict)
    primitives: dict[str, PrimitiveDef] = field(default_factory=dict)
    warnings: list[str] = field(default_factory=list)
    errors: list[str] = field(default_factory=list)

    def warn(self, msg: str) -> None:
        self.warnings.append(msg)

    def fail(self, msg: str) -> None:
        self.errors.append(msg)

    def freeze(self) -> "CorpusIR":
        return CorpusIR(
            fingerprint=self.fingerprint,
            upd_paths=self.upd_paths,
            targets=MappingProxyType(dict(self.targets)),
            primitives=MappingProxyType(dict(self.primitives)),
            warnings=tuple(self.warnings),
        )


@dataclass(frozen=True)
class CorpusIR:
    """Immutable, target-agnostic view of the validated UPD corpus.

    Built once per UPD fingerprint and shared by every per-target generation
    run — the corpus half of the corpus/target split (paper §4.2 "ongoing
    process": regeneration for another target must not re-validate)."""

    fingerprint: str
    upd_paths: tuple[str, ...]
    targets: Mapping[str, TargetDef]
    primitives: Mapping[str, PrimitiveDef]
    warnings: tuple[str, ...] = ()

    @classmethod
    def from_defs(cls, targets: dict[str, TargetDef] | None = None,
                  primitives: dict[str, PrimitiveDef] | None = None,
                  fingerprint: str = "adhoc",
                  upd_paths: tuple[str, ...] = ()) -> "CorpusIR":
        """Build a corpus directly from typed defs (tests, custom pipelines)."""
        return cls(
            fingerprint=fingerprint,
            upd_paths=upd_paths,
            targets=MappingProxyType(dict(targets or {})),
            primitives=MappingProxyType(dict(primitives or {})),
        )


@dataclass
class GenerationResult:
    """Per-target mutable state flowing through the *target* pipeline
    (select → [bench-select] → generate → testgen/buildgen/docgen).

    The corpus half (``corpus``) is immutable and shared; everything mutable
    here is specific to one (target, config) generation run."""

    config: "GenConfig"
    corpus: CorpusIR
    # selection[primitive][ctype] -> Selection  (for config.target only)
    selection: dict[str, dict[str, Selection]] = field(default_factory=dict)
    files: list[GeneratedFile] = field(default_factory=list)
    warnings: list[str] = field(default_factory=list)
    errors: list[str] = field(default_factory=list)
    meta: dict[str, Any] = field(default_factory=dict)

    @property
    def targets(self) -> Mapping[str, TargetDef]:
        return self.corpus.targets

    @property
    def primitives(self) -> Mapping[str, PrimitiveDef]:
        return self.corpus.primitives

    def warn(self, msg: str) -> None:
        self.warnings.append(msg)

    def fail(self, msg: str) -> None:
        self.errors.append(msg)


@dataclass(frozen=True)
class GenConfig:
    """Generator invocation configuration (paper: CLI of ``main.py`` + cmake glue)."""

    target: str                          # SRU name to generate for
    hardware_flags: tuple[str, ...] | None = None   # override probed flags (paper: --targets)
    only: tuple[str, ...] | None = None  # cherry-picked primitive subset (paper §1 "slim")
    package_name: str = "tsl"
    emit_tests: bool = True
    emit_docs: bool = False
    emit_build: bool = True
    use_bench_selection: bool = False    # beyond-paper §4.2 adaptive selection
    bench_smoke: bool = False            # cap bench n_iter at 1 (CI path check)
    upd_paths: tuple[str, ...] = ()      # extra UPD search paths (extensibility studies)
    build_root: str | None = None        # artifact-cache root (None -> build/tsl)
    shared_store: bool = False           # multi-process store root: lockfile
                                         # writer election + publish-by-rename
                                         # (also via TSL_STORE_ROOT env var)
