"""Public generator API: generate → materialize on disk → import.

The C++ TSL is generated into a header tree and compiled into the consumer;
the JAX analogue generates a Python package into the artifact cache under
``build/tsl/`` and imports it.

Incremental multi-target engine (paper Fig 7a + §4.2 "ongoing process"):

* the corpus (loaded + validated UPD) is built once per fingerprint and
  shared across targets — ``generate_all`` re-validates NOTHING when
  generating a second target;
* every generated package is content-addressed by
  (UPD fingerprint, target, probed hardware flags, generator version,
  config variant), so ``load_library()`` with unchanged inputs is a pure
  cache hit that never re-runs a single GPO;
* editing any UPD document, changing the hardware flags, or bumping
  :data:`~.cache.GENERATOR_VERSION` each force regeneration.
"""

from __future__ import annotations

import dataclasses
import importlib
import sys
from pathlib import Path
from types import ModuleType

from . import hwprobe, loader
from .cache import ArtifactCache, CacheKey, variant_digest
from .corpus import load_corpus
from .model import CorpusIR, GenConfig, GenerationResult
from .pipeline import core_pipeline

DEFAULT_BUILD_ROOT = Path(__file__).resolve().parents[3] / "build" / "tsl"

_IN_PROCESS_CACHE: dict[str, ModuleType] = {}


def effective_hardware_flags(config: GenConfig,
                             corpus: CorpusIR | None = None) -> tuple[str, ...]:
    """Resolve the hardware flags that key this generation run: the explicit
    override if given, else the target SRU's own flags. On warm cache paths
    (no corpus built) the flags come from the raw UPD document — a cache hit
    must not pay for validation."""
    if config.hardware_flags is not None:
        return tuple(sorted(config.hardware_flags))
    if corpus is not None and config.target in corpus.targets:
        return tuple(sorted(corpus.targets[config.target].flags))
    for doc in loader.load_raw_targets(config.upd_paths):
        if doc.get("name") == config.target:
            return tuple(sorted(doc.get("lscpu_flags", ())))
    return ()


def artifact_key(config: GenConfig, fingerprint: str,
                 corpus: CorpusIR | None = None) -> CacheKey:
    from . import cache as _cache  # read GENERATOR_VERSION at call time

    return CacheKey(
        fingerprint=fingerprint,
        target=config.target,
        hardware_flags=effective_hardware_flags(config, corpus),
        generator_version=_cache.GENERATOR_VERSION,
        variant=variant_digest(config),
    )


def resolve_store(config: GenConfig, key: CacheKey,
                  build_root: Path | None = None
                  ) -> tuple[ArtifactCache, Path]:
    """The artifact store this run writes to. ``TSL_STORE_ROOT`` (a fleet's
    one shared directory) or ``config.shared_store`` select the shared
    multi-process mode, which namespaces artifacts by the key's hardware
    class; otherwise the classic private ``build/tsl`` root."""
    import os

    env_root = os.environ.get("TSL_STORE_ROOT")
    shared = bool(env_root) or config.shared_store
    root = Path(build_root or config.build_root or env_root
                or DEFAULT_BUILD_ROOT)
    if shared:
        return ArtifactCache(root, shared=True,
                             namespace=key.hw_namespace()), root
    return ArtifactCache(root), root


def generate_library(config: GenConfig, build_root: Path | None = None,
                     *, force: bool = False,
                     corpus: CorpusIR | None = None
                     ) -> tuple[Path, GenerationResult | None]:
    """Run the target pipeline (or hit the artifact cache) for one target.

    Returns (pkg_dir, result); result is None on a cache hit — no GPO ran.
    On a shared store root the GPO run is guarded by writer election: one
    process generates while every other blocks on ``wait_for`` and returns
    the published package as a warm hit (zero GPOs re-run)."""
    fingerprint = (corpus.fingerprint if corpus is not None
                   else loader.upd_fingerprint(config.upd_paths))
    key = artifact_key(config, fingerprint, corpus)
    store, build_root = resolve_store(config, key, build_root)
    pkg = store.package_name(config.package_name, key)
    hit = store.lookup(pkg)
    if hit is not None and not force:
        return hit, None

    if store.shared and not force:
        while not store.acquire_writer(pkg):
            hit = store.wait_for(pkg)
            if hit is not None:
                return hit, None
            # writer died unpublished: loop re-runs the election
        try:
            hit = store.lookup(pkg)     # published between lookup and lock
            if hit is not None:
                return hit, None
            return _generate_into(config, store, build_root, pkg, key, corpus,
                                  fingerprint)
        finally:
            store.release_writer(pkg)
    return _generate_into(config, store, build_root, pkg, key, corpus,
                          fingerprint)


def _generate_into(config: GenConfig, store: ArtifactCache, build_root: Path,
                   pkg: str, key: CacheKey, corpus: CorpusIR | None,
                   fingerprint: str) -> tuple[Path, GenerationResult]:
    if corpus is None:
        corpus = load_corpus(config.upd_paths, fingerprint=fingerprint)
    run_cfg = dataclasses.replace(config, package_name=pkg,
                                  build_root=str(build_root))
    result = core_pipeline(run_cfg).run(run_cfg, corpus=corpus)
    return store.commit(pkg, key, result.files), result


def generate_all(targets: tuple[str, ...] | list[str] | None = None,
                 build_root: Path | None = None, *, force: bool = False,
                 corpus: CorpusIR | None = None,
                 upd_paths: tuple[str, ...] = (),
                 **config_kwargs) -> dict[str, Path]:
    """Generate libraries for several targets off ONE shared corpus.

    ``targets=None`` means every target the corpus defines. Validation and
    template checking run at most once regardless of target count."""
    if corpus is None:
        corpus = load_corpus(tuple(upd_paths))
    names = list(targets) if targets is not None else sorted(corpus.targets)
    out: dict[str, Path] = {}
    for name in names:
        cfg = GenConfig(target=name, upd_paths=tuple(upd_paths),
                        **config_kwargs)
        out[name], _ = generate_library(cfg, build_root, force=force,
                                        corpus=corpus)
    return out


def load_library(target: str = "auto", *, only: tuple[str, ...] | None = None,
                 hardware_flags: tuple[str, ...] | None = None,
                 emit_tests: bool = True, emit_docs: bool = False,
                 use_bench_selection: bool = False,
                 upd_paths: tuple[str, ...] = (),
                 build_root: Path | None = None,
                 force: bool = False) -> ModuleType:
    """Generate (cached) and import the TSL for ``target``.

    ``target='auto'`` probes the live backend (paper: cpuinfo flags feeding
    the generator from cmake). Warm path — unchanged fingerprint + hardware
    flags — is an artifact-cache hit: no validation, no generation."""
    if target == "auto":
        target = hwprobe.live_target()
    config = GenConfig(
        target=target,
        hardware_flags=hardware_flags,
        only=tuple(only) if only else None,
        emit_tests=emit_tests,
        emit_docs=emit_docs,
        use_bench_selection=use_bench_selection,
        upd_paths=tuple(upd_paths),
    )
    build_root = Path(build_root or DEFAULT_BUILD_ROOT)
    pkg_dir, _ = generate_library(config, build_root, force=force)
    pkg = pkg_dir.name
    if pkg in _IN_PROCESS_CACHE and not force:
        return _IN_PROCESS_CACHE[pkg]
    pkg_root = str(pkg_dir.parent)
    if pkg_root not in sys.path:
        sys.path.insert(0, pkg_root)
    if force and pkg in sys.modules:
        for m in [m for m in sys.modules if m == pkg or m.startswith(pkg + ".")]:
            del sys.modules[m]
    mod = importlib.import_module(pkg)
    _IN_PROCESS_CACHE[pkg] = mod
    return mod
