"""Public wrapper for fused paged attention: shapes the pool and the GQA
query block for the Pallas kernel, restores the logical (B, H, SQ, D) view.

The page size is NOT a parameter — it is read off the pool's page axis, so
one definition serves whatever page size the bench selected for
``cache_page_read`` (the SVE length-agnostic discipline applied twice over:
page size owned by the memory primitive, block_k owned by this one). The
effective key block is clamped to divide the page: candidates smaller than
the page tile it; anything else degrades to one block per page.

int8 pools (``k_scale``/``v_scale`` present) route to the jnp reference,
whose scan dequantizes per touched page — still inside the primitive, never
at a park/activate boundary.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import kernel, ref


def _sublane_pad(x, mult=8):
    r = x.shape[2]
    rp = max(mult, -(-r // mult) * mult)
    if rp == r:
        return x
    pad = [(0, 0)] * x.ndim
    pad[2] = (0, rp - r)
    return jnp.pad(x, pad)


@partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def paged_attention(q, k_pool, v_pool, tables, kv_len, *, k_scale=None,
                    v_scale=None, scale=None, block_k: int = 64,
                    interpret: bool = False):
    """q (B,H,SQ,D); k_pool/v_pool (KH, n_pages, page, D); tables (B,P) int32
    page ids; kv_len (B,) int32 (scalars broadcast). Returns (B,H,SQ,D)."""
    b, h, sq, d = q.shape
    kh, _, page, _ = k_pool.shape
    group = h // kh
    kvl = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    if k_scale is not None:
        return ref.paged_attention_ref(q, k_pool, v_pool, tables, kvl,
                                       k_scale=k_scale, v_scale=v_scale,
                                       scale=scale)
    bk = block_k if (block_k <= page and page % block_k == 0) else page
    rq = group * sq
    q4 = _sublane_pad(q.reshape(b, kh, rq, d))
    out = kernel.paged_attention_4d(
        q4, k_pool.reshape(kh, -1, d), v_pool.reshape(kh, -1, d),
        tables, kvl, sq=sq, page=page, block_k=bk, scale=scale,
        interpret=interpret)
    return out[:, :, :rq].reshape(b, h, sq, d)


__all__ = ["paged_attention", "ref"]
