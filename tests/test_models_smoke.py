"""Per-arch smoke tests (task brief deliverable (f)): REDUCED config of each
family, one forward/train step on CPU, output shapes + no NaNs, plus
decode-vs-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.nn.model import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_prefix, cfg.d_model)) * 0.02,
            cfg.dtype)
    if cfg.family == "audio":
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)) * 0.02, cfg.dtype)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_loss(arch_id):
    cfg = get_config(arch_id).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits = model.forward_logits(params, batch)
    s_total = 16 + (cfg.vision_prefix if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_total, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    from repro.train.optimizer import OptConfig
    from repro.train.step import init_train_state, make_train_step

    cfg = get_config(arch_id).reduced()
    model = build_model(cfg)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    step = make_train_step(model, opt_cfg)
    state = init_train_state(model, opt_cfg, KEY)
    batch = _batch(cfg)
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    flat0 = jax.tree.leaves(init_train_state(model, opt_cfg, KEY)["params"])
    flat1 = jax.tree.leaves(state["params"])
    assert any(not np.allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32))
               for a, b in zip(flat0, flat1))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_matches_forward(arch_id):
    cfg = get_config(arch_id).reduced()
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=8.0)   # avoid token-drop divergence
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 12
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    batch = {"tokens": toks[:, :S]}
    full = {"tokens": toks}
    if cfg.family == "vlm":
        ve = jnp.asarray(rng.normal(size=(B, cfg.vision_prefix, cfg.d_model)) * 0.02,
                         cfg.dtype)
        batch["vision_embeds"] = ve
        full["vision_embeds"] = ve
    if cfg.family == "audio":
        ae = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.02, cfg.dtype)
        batch["audio_embeds"] = ae
        full["audio_embeds"] = ae
    want = np.asarray(model.forward_logits(params, full)[:, -1], np.float32)
    _, state = model.prefill(params, batch, S + 4 + (cfg.vision_prefix or 0))
    # decode position includes the vision-prefix tokens for VLMs
    pos = S + (cfg.vision_prefix if cfg.family == "vlm" else 0)
    got, _ = model.decode_step(params, state, toks[:, S:S + 1], jnp.int32(pos))
    got = np.asarray(got, np.float32)
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 2e-2, err


@pytest.mark.parametrize("arch_id", ["qwen1.5-0.5b", "rwkv6-7b", "zamba2-7b"])
def test_multi_step_decode_no_nans(arch_id):
    cfg = get_config(arch_id).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 8
    batch = _batch(cfg, B, S)
    batch.pop("labels")
    _, state = model.prefill(params, batch, S + 8)
    tok = jnp.ones((B, 1), jnp.int32)
    dec = jax.jit(model.decode_step)
    for i in range(6):
        logits, state = dec(params, state, tok, jnp.int32(S + i))
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)


def test_loss_decreases_under_training():
    """Integration: 20 steps of AdamW on a fixed tiny batch reduce the loss."""
    from repro.train.optimizer import OptConfig
    from repro.train.step import init_train_state, make_train_step

    cfg = get_config("qwen1.5-0.5b").reduced()
    model = build_model(cfg)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=2, total_steps=20)
    step = jax.jit(make_train_step(model, opt_cfg))
    state = init_train_state(model, opt_cfg, KEY)
    batch = _batch(cfg, B=4, S=32)
    losses = []
    for _ in range(20):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_param_counts_match_analytic():
    """cfg.param_count() (used for MODEL_FLOPS) vs actual init tree size."""
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id).reduced()
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        expected = cfg.param_count()
        # analytic model tracks the big matrices; allow 15% for small vectors
        assert abs(actual - expected) / actual < 0.15, \
            (arch_id, actual, expected)
