"""Sharding-rule unit tests (no 512-device env needed: rules are pure)."""

import jax
import jax.numpy as jnp
import numpy as np


def _sds(shape, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-in — NEVER allocate multi-GiB test params."""
    return jax.ShapeDtypeStruct(shape, dtype)
from jax.sharding import PartitionSpec as P

from repro.dist import sharding


class _FakeMesh:
    """Shape-only stand-in so rules can be tested against the production mesh
    geometry without 512 devices."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)
        self.axis_sizes = shape


def test_param_rules_production_geometry():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    params = {
        "embed": _sds((64000, 7168)),
        "blocks": {
            "attn": {"wq": _sds((60, 7168, 7168)),
                     "wo": _sds((60, 7168, 7168))},
            "moe": {"w_gate": _sds((35, 128, 7168, 4864))},
            "attn_norm": {"w": _sds((60, 7168))},
        },
        "head": _sds((7168, 64000)),
    }
    specs = sharding.param_specs(mesh, params)
    assert specs["embed"] == P("model", "data")
    assert specs["blocks"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["blocks"]["attn"]["wo"] == P(None, "model", "data")
    assert specs["blocks"]["moe"]["w_gate"] == P(None, None, "data", "model")
    assert specs["blocks"]["attn_norm"]["w"] == P()          # 1D replicated
    assert specs["head"] == P("data", "model")


def test_param_rules_multipod_folds_dp():
    mesh = _FakeMesh((2, 16, 16), ("pod", "data", "model"))
    params = {"head": _sds((12288, 32768))}
    specs = sharding.param_specs(mesh, params)
    assert specs["head"] == P(("pod", "data"), "model")


def test_tiny_dims_not_oversharded():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    params = {"wq": _sds((8, 4))}   # smaller than mesh
    specs = sharding.param_specs(mesh, params)
    assert specs["wq"] == P(None, None)


def test_state_specs_kv_cache_sequence_parallel():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    state = {"k": _sds((60, 128, 8, 32768, 128)),
             "v": _sds((60, 128, 8, 32768, 128))}
    specs = sharding.state_specs(mesh, state)
    assert specs["k"] == P(None, "data", None, "model", None)


def test_state_specs_batch1_keeps_seq_sharding():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    state = {"k": _sds((81, 1, 32, 524288, 112))}
    specs = sharding.state_specs(mesh, state)
    # batch of 1 cannot shard on data; sequence still shards on model
    assert specs["k"] == P(None, None, None, "model", None)


def test_state_specs_huge_batch_does_not_steal_model_axis():
    """Decode batch larger than max_len: batch stays on data, seq on model."""
    mesh = _FakeMesh((16, 16), ("data", "model"))
    state = {"k": _sds((60, 4096, 8, 1024, 128))}
    specs = sharding.state_specs(mesh, state)
    assert specs["k"] == P(None, "data", None, "model", None)


def test_batch_spec_divisibility():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    assert sharding.batch_spec(mesh, 256) == P("data", None)
    assert sharding.batch_spec(mesh, 1) == P(None)


def test_logical_constraint_noop_without_mesh():
    x = jnp.zeros((4, 8))
    y = sharding.logical_constraint(x, "batch", None)
    assert y.shape == x.shape

def test_output_projection_flip_list_complete():
    """Every declared output-side projection name flips to (model, data) —
    the whole list, not just wo (rules untested since PR 1)."""
    mesh = _FakeMesh((16, 16), ("data", "model"))
    params = {name: _sds((4096, 4096)) for name in sharding._OUTPUT_PROJ_NAMES}
    params["wq"] = _sds((4096, 4096))
    specs = sharding.param_specs(mesh, params)
    for name in sharding._OUTPUT_PROJ_NAMES:
        assert specs[name] == P("model", "data"), name
    assert specs["wq"] == P("data", "model")     # input-side: NOT flipped


def test_guard_falls_back_on_missing_axis_name():
    """A mesh WITHOUT a model axis is a degenerate axis group of size 1: the
    rules written for (data, model) must unshard those dims, not error."""
    mesh = _FakeMesh((8,), ("data",))
    params = {"wq": _sds((4096, 4096)), "wo": _sds((4096, 4096))}
    specs = sharding.param_specs(mesh, params)
    assert specs["wq"] == P("data", None)
    assert specs["wo"] == P(None, "data")
    state = {"k": _sds((4, 16, 8, 4096, 128))}
    assert sharding.state_specs(mesh, state)["k"] == \
        P(None, "data", None, None, None)


def test_guard_falls_back_on_size1_axis_group():
    """An axis the mesh carries at size 1 must also unshard (device_put with
    a size-1 entry is legal but noisy; the guard folds it to None)."""
    mesh = _FakeMesh((4, 1), ("data", "model"))
    params = {"wq": _sds((4096, 4096))}
    assert sharding.param_specs(mesh, params)["wq"] == P("data", None)
    mesh2 = _FakeMesh((1, 4), ("data", "model"))
    assert sharding.param_specs(mesh2, params)["wq"] == P(None, "model")


def test_mesh_size_helpers():
    assert sharding.dp_size(None) == 1 and sharding.tp_size(None) == 1
    mesh = _FakeMesh((2, 4), ("data", "model"))
    assert sharding.dp_size(mesh) == 2
    assert sharding.tp_size(mesh) == 4
    assert sharding.mesh_shards(mesh) == 8
    pod = _FakeMesh((2, 8, 4), ("pod", "data", "model"))
    assert sharding.dp_size(pod) == 16           # pod folds into DP
    assert sharding.mesh_axis_sizes(pod) == {"pod": 2, "data": 8, "model": 4}


def test_state_specs_token_axes_contract():
    """Family-declared token axes override the largest-dim heuristic: a
    recurrent leaf (token axis None) must NOT put a feature axis on model —
    sharded-reduction reassociation there breaks decode equivalence."""
    mesh = _FakeMesh((2, 4), ("data", "model"))
    state = {"k": _sds((2, 4, 4, 64, 16)),       # KV cache: token axis 3
             "s": _sds((2, 4, 4, 16, 16))}       # wkv state: NO token axis
    heur = sharding.state_specs(mesh, state)
    assert heur["s"] == P(None, "data", None, "model", None)  # heuristic: wrong
    specs = sharding.state_specs(mesh, state,
                                 token_axes={"k": 3, "s": None})
    assert specs["k"] == P(None, "data", None, "model", None)
    assert specs["s"] == P(None, "data", None, None, None)


def test_state_specs_batch_axes_contract():
    """Grouped-scan leaves (zamba h/conv) carry the request axis at 2 — the
    declared batch axis takes the data entry, not the default axis 1."""
    mesh = _FakeMesh((2, 4), ("data", "model"))
    state = {"h": _sds((2, 3, 4, 8, 8, 16))}
    specs = sharding.state_specs(mesh, state, token_axes={"h": None},
                                 batch_axes={"h": 2})
    assert specs["h"] == P(None, None, "data", None, None, None)
