"""Mesh-aware sharding rules (pure: no devices needed to compute specs).

Conventions (production LM geometry):

* mesh axes: one ``model`` (TP) axis; every other axis is data-parallel and
  gets folded into a single logical DP group (``("pod", "data")`` on a
  multi-pod mesh) — so rules written for (data, model) generalize.
* parameters: matrices shard (row -> data, col -> model) except output
  projections (``wo``/``w_down``/``out_proj``/...) which flip, embeddings
  (vocab -> model, d_model -> data) and norm vectors (replicated). Leading
  layer-stack axes are never sharded.
* decode state: KV caches shard batch on data and SEQUENCE on model
  (sequence-parallel decode) — the largest axis wins the model axis.
* every rule applies a divisibility guard: a dim that does not divide by the
  axis group size stays unsharded instead of erroring at device_put time.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# parameter names whose LAST TWO dims are (model, data) instead of (data, model):
# output-side projections, whose input dim arrives model-sharded from the heads
_OUTPUT_PROJ_NAMES = frozenset(
    {"wo", "w_down", "w_out", "out_proj", "cm_wv", "w_o", "wv_out"})

# logical activation axis -> physical mesh axis family
_LOGICAL_TO_PHYSICAL = {
    "batch": "__data__",
    "expdp": "__data__",
    "heads": "model",
    "model": "model",
    "vocab": "model",
    "seqtp": "model",
    "kvseq": "model",
}


# ---------------------------------------------------------------------------
# mesh introspection helpers (work on jax.sharding.Mesh AND shape-only fakes)

def _axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(tuple(mesh.axis_names), tuple(np.shape(mesh.devices))))

def _data_axes(mesh) -> tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n != "model")


def _data_entry(mesh):
    """The PartitionSpec entry for the folded DP group."""
    d = _data_axes(mesh)
    if not d:
        return None
    return d[0] if len(d) == 1 else d


def _entry_size(mesh, entry) -> int:
    # An axis name the mesh does not carry is a degenerate axis group of
    # size 1 — the guard then unshards that dim instead of erroring (rules
    # written for (data, model) must run unchanged on a data-only mesh).
    sizes = _axis_sizes(mesh)
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        total = 1
        for n in entry:
            total *= sizes.get(n, 1)
        return total
    return sizes.get(entry, 1)


def _guard(mesh, shape, entries):
    """Divisibility guard: unshard any dim the mesh does not divide.

    Falls back (entry -> None) when the dim does not divide the axis group
    size AND when the axis group itself is degenerate: size 1, or an axis
    name the mesh does not have at all."""
    out = []
    for dim, e in zip(shape, entries):
        size = _entry_size(mesh, e)
        if e is not None and (size <= 1 or dim % size != 0 or dim < size):
            e = None
        out.append(e)
    return out


def mesh_axis_sizes(mesh) -> dict[str, int]:
    """Axis-name -> size for a Mesh (or any shape-only stand-in)."""
    return _axis_sizes(mesh)


def dp_size(mesh) -> int:
    """Folded data-parallel group size (1 on a model-only or empty mesh)."""
    if mesh is None:
        return 1
    return _entry_size(mesh, _data_entry(mesh))


def tp_size(mesh) -> int:
    """Tensor-parallel (``model`` axis) size (1 when the mesh has none)."""
    if mesh is None:
        return 1
    return _axis_sizes(mesh).get("model", 1)


def mesh_shards(mesh) -> int:
    """Total shard count = dp * tp (1 when unmeshed)."""
    return dp_size(mesh) * tp_size(mesh)


# ---------------------------------------------------------------------------
# parameter rules

def _param_spec_one(mesh, path: tuple[str, ...], shape) -> P:
    name = path[-1] if path else ""
    if any("norm" in part for part in path) or len(shape) <= 1:
        return P()
    data = _data_entry(mesh)
    lead = [None] * (len(shape) - 2)
    if "embed" in name:
        row, col = "model", data
    elif name in _OUTPUT_PROJ_NAMES:
        row, col = "model", data
    else:  # generic input-side matrix, router, head, moe experts, ...
        row, col = data, "model"
    entries = _guard(mesh, shape, lead + [row, col])
    return P(*entries)


def param_specs(mesh, params):
    """PartitionSpec pytree for a parameter pytree (leaves need ``.shape``)."""

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        return _param_spec_one(mesh, path, tuple(node.shape))

    return walk((), params)


def param_shardings(mesh, params):
    """NamedSharding pytree matching :func:`param_specs`."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(mesh, params),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# decode-state rules

def _state_spec_one(mesh, shape, token_axis="auto", batch_axis=1) -> P:
    if len(shape) < 3:
        return P(*([None] * len(shape)))
    entries = [None] * len(shape)
    sizes = _axis_sizes(mesh)
    model_size = sizes.get("model", 1)
    batch_i = batch_axis
    if token_axis == "auto":
        # sequence axis = largest NON-batch dim (a huge decode batch must not
        # steal the model axis from the sequence dim)
        seq_i = max((i for i in range(len(shape)) if i != batch_i),
                    key=lambda i: shape[i])
    else:
        # family-declared token axis (state_page_axes contract); None marks a
        # fixed-size recurrent leaf with NO sequence axis — sharding one of
        # its feature/contraction axes on ``model`` would reassociate the
        # reductions that consume it and break token-for-token equivalence,
        # so such leaves stay batch-on-data only.
        seq_i = token_axis
    if (seq_i is not None and seq_i != batch_i and model_size > 1
            and shape[seq_i] % model_size == 0 and shape[seq_i] >= model_size):
        entries[seq_i] = "model"
    data = _data_entry(mesh)
    if data is not None:
        dsize = _entry_size(mesh, data)
        if dsize > 1 and shape[batch_i] % dsize == 0 and shape[batch_i] >= dsize:
            entries[batch_i] = data
    return P(*entries)


def state_specs(mesh, state, token_axes=None, batch_axes=None):
    """PartitionSpec pytree for a decode-state pytree (KV caches, SSM states).

    ``token_axes`` (optional, dict-state only): name -> token-axis int or
    None, the :func:`state_page_axes` contract each model family declares.
    When given it overrides the largest-dim heuristic — leaves declared
    ``None`` (recurrent tails) get no ``model`` entry at all.
    ``batch_axes`` (optional, dict-state only): name -> request-axis int,
    the ``state_batch_axes`` contract (defaults to 1 per leaf)."""
    if token_axes is not None and isinstance(state, dict):
        batch_axes = batch_axes or {}
        return {
            k: _state_spec_one(mesh, tuple(v.shape),
                               token_axis=token_axes.get(k, "auto"),
                               batch_axis=batch_axes.get(k, 1))
            for k, v in state.items()
        }
    return jax.tree.map(lambda leaf: _state_spec_one(mesh, tuple(leaf.shape)), state)


def state_shardings(mesh, state, token_axes=None, batch_axes=None):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        state_specs(mesh, state, token_axes=token_axes,
                                    batch_axes=batch_axes),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batch rules

def batch_spec(mesh, batch_size: int) -> P:
    """Spec for a (B, ...) batch leaf given its leading dim."""
    data = _data_entry(mesh)
    size = _entry_size(mesh, data)
    if data is not None and size > 1 and batch_size % size == 0 and batch_size >= size:
        return P(data, None)
    return P(None)


def batch_shardings(mesh, batch):
    """NamedSharding pytree for an input batch: leading dim on data, rest replicated."""

    def one(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return NamedSharding(mesh, P())
        data = _data_entry(mesh)
        entries = _guard(mesh, shape, [data] + [None] * (len(shape) - 1))
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(one, batch)


# ---------------------------------------------------------------------------
# in-graph logical constraints

def _ambient_mesh():
    try:
        from jax.interpreters import pxla

        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # pragma: no cover - jax internals moved
        pass
    return None


def ambient_dp_size() -> int:
    """Total data-parallel size of the ambient mesh (1 when unmeshed)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return 1
    return _entry_size(mesh, _data_entry(mesh))


def logical_constraint(x, *axes):
    """Pin ``x`` to a logical layout ("batch"/"heads"/"vocab"/"seqtp"/...).

    A no-op outside a mesh context, and per-dim a no-op when the mesh does not
    divide that dim — safe to sprinkle on every residual boundary."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    entries = []
    for i, axis in enumerate(axes):
        entry = None
        if axis is not None:
            phys = _LOGICAL_TO_PHYSICAL.get(axis)
            if phys == "__data__":
                entry = _data_entry(mesh)
            elif phys is not None:
                entry = phys
        if entry is not None:
            size = _entry_size(mesh, entry)
            if size <= 1 or i >= x.ndim or x.shape[i] % size != 0:
                entry = None
        entries.append(entry)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))
