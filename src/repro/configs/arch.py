"""Architecture configuration dataclass + shape-cell definitions.

One ``configs/<id>.py`` per assigned architecture instantiates ArchConfig with
the exact published numbers; ``reduced()`` derives the CPU smoke-test variant
(same family, tiny widths).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # attention details
    qk_norm: bool = False             # qwen3
    qkv_bias: bool = False            # qwen1.5
    rope_theta: float = 1e4
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual: bool = False  # arctic
    dense_residual_ff: int = 0        # arctic's parallel dense MLP width
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0                # mamba2 N
    ssm_head_dim: int = 64            # mamba2 P
    d_inner_mult: int = 2             # mamba2 d_inner = mult * d_model
    attn_every: int = 0               # zamba2: shared attn block every k layers
    conv_width: int = 4
    rwkv_head_dim: int = 64           # rwkv6 K=V
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    # VLM stub
    vision_prefix: int = 0            # patch-embedding stub tokens prepended
    # misc
    act: str = "swiglu"               # swiglu | gelu
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""                  # provenance tag from the assignment table

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head table size: vocab padded to a multiple of 256 so
        the vocab dim shards on any mesh axis (standard production practice;
        whisper's 51865 and internvl2's 92553 are otherwise unshardable and
        waste model-axis FLOPs on the head matmul)."""
        return -(-self.vocab // 256) * 256

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def decode_prefix(self) -> int:
        """Cache rows the prefill prepends BEFORE the prompt (vlm vision
        embeddings): they consume decode slot-table budget exactly like
        prompt tokens, so every serving-side length calculation must add
        this. Single source of truth for engine/admission/CLI."""
        return self.vision_prefix if self.family == "vlm" else 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k cell? (SSM / hybrid decode paths)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests (one fwd/train step)."""
        return self.replace(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2 if not self.attn_every else 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab=256,
            head_dim=16,
            n_experts=min(self.n_experts, 4),
            dense_residual_ff=64 if self.moe_dense_residual else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=8,
            rwkv_head_dim=16,
            attn_every=2 if self.attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            vision_prefix=min(self.vision_prefix, 8),
            dtype="float32",
        )

    # -- analytic parameter count (roofline MODEL_FLOPS = 6·N·D) -------------

    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.hd
        h, kh = self.n_heads, self.n_kv_heads
        n = 0
        n += self.vocab * d                       # embed
        if not self.tie_embeddings:
            n += d * self.vocab                   # lm head
        def attn_params() -> int:
            p = d * (h * hd) + 2 * d * (kh * hd) + (h * hd) * d
            if self.qkv_bias:
                p += h * hd + 2 * kh * hd
            if self.qk_norm:
                p += 2 * hd
            return p
        def mlp_params(ff: int) -> int:
            if self.act == "swiglu":
                return 3 * d * ff
            return 2 * d * ff
        if self.family in ("dense", "vlm"):
            per = attn_params() + mlp_params(self.d_ff) + 2 * d
            n += self.n_layers * per
        elif self.family == "moe":
            per = attn_params() + 2 * d
            per += self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            if self.moe_dense_residual:
                per += mlp_params(self.dense_residual_ff or d)
            n += self.n_layers * per
            if active_only:
                n = self.vocab * d * (1 if self.tie_embeddings else 2)
                per = attn_params() + 2 * d + d * self.n_experts
                per += self.experts_per_token * 3 * d * self.d_ff
                if self.moe_dense_residual:
                    per += mlp_params(self.dense_residual_ff or d)
                n += self.n_layers * per
        elif self.family == "hybrid":
            d_in = self.d_inner_mult * d
            nh_ssm = d_in // self.ssm_head_dim
            per = 2 * d                            # norms
            per += d * (2 * d_in + 2 * self.ssm_state + nh_ssm)   # in_proj
            per += self.conv_width * d_in          # conv
            per += d_in * d                        # out_proj
            per += 2 * nh_ssm + d_in               # A_log, dt_bias, D skip + gate norm
            n += self.n_layers * per
            n += attn_params() + 2 * d             # ONE shared attention block
        elif self.family == "ssm":                 # rwkv6
            k = self.rwkv_head_dim
            nh_r = d // k
            per = 2 * d
            per += 5 * d + 4 * d * d + nh_r * k    # time-mix: mus, r/k/v/g proj, u
            per += d * 64 + 64 * d                 # w lora
            per += d * d                           # output proj
            per += 2 * d + d * self.d_ff + self.d_ff * d   # channel mix
            n += self.n_layers * per
        elif self.family == "audio":
            per = attn_params() + mlp_params(self.d_ff) + 2 * d
            n += self.n_enc_layers * per                       # encoder
            dec_per = attn_params() * 2 + mlp_params(self.d_ff) + 3 * d
            n += self.n_layers * dec_per                       # decoder (self+cross)
        return n
