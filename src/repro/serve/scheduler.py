"""Slot-table scheduler for per-step continuous batching.

Pure host-side control plane — no jax in here. The engine owns the device
state; the scheduler owns the request queue, the per-slot lifecycle
(free -> occupied -> free), per-request SLA/deadline accounting, and the
admission decision. Admission is roofline-informed: the cost model consumes
the SAME analytic ``lib.cost()`` terms the generator selected the primitive
implementations with (PAPER.md §cost channel), so "can this request meet its
deadline on this hardware at this batch size" is answered from the UPD cost
formulas + the v5e roofline constants, not from guesswork.

Refusals are permanent and carry a reason (``over_budget`` — the request
does not fit the slot table's max_len; ``sla_infeasible`` — even the
best-case estimate misses its deadline), so callers can re-shape and resubmit
rather than letting a doomed request occupy a slot.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass

from repro.launch.roofline import HBM_BW, PEAK_FLOPS


@dataclass
class Request:
    """One serving request: a prompt, a generation budget, an optional SLA.

    ``sla_s`` is an end-to-end latency deadline in seconds, measured from
    ``submit`` — both admission (projection) and the final hit/miss
    accounting are against it.
    """

    rid: str
    tokens: object                  # prompt token array (1-D, int)
    gen_len: int
    sla_s: float | None = None
    embeds: object | None = None    # per-request media: vlm (prefix, D)
                                    # vision / audio (enc_len, D) frames
    arrival_s: float = 0.0          # stamped by Scheduler.submit

    @property
    def prompt_len(self) -> int:
        return int(len(self.tokens))


@dataclass
class RequestMetrics:
    """Per-request accounting the engine reports (and tests assert on)."""

    rid: str
    slot: int = -1
    prompt_len: int = 0
    gen_len: int = 0
    tokens_out: int = 0
    ttft_s: float = 0.0             # arrival -> first token (prefill + queue)
    decode_tokens_per_s: float = 0.0
    latency_s: float = 0.0          # arrival -> last token
    sla_s: float | None = None
    sla_met: bool | None = None     # None: no SLA attached
    admitted_at_step: int = -1      # engine decode-step index at admission


@dataclass
class Refusal:
    rid: str
    reason: str


class CostModelAdmission:
    """Roofline admission driven by the generated library's cost channel.

    A decode step over the full slot table is modeled as memory-bound:
      bytes/step = param bytes (weights stream once per token)
                 + n_attn_layers x lib.cost("attention_decode", "bytes", ...)
      step_s     = bytes / HBM_BW
    Prefill is modeled as compute-bound: 2·N·prompt_len / PEAK_FLOPS.

    Both are deliberately idealized (roofline = best case); a request whose
    deadline fails even the BEST case is hopeless, which makes refusal sound.
    ``lib.cost`` raising KeyError (a generated package without the term) falls
    back to the same formula evaluated analytically, so admission never takes
    the serving path down with it.
    """

    def __init__(self, cfg, batch: int, max_len: int,
                 enc_len: int | None = None):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.enc_len = enc_len          # audio: fixed cross K/V length
        self.prefix = cfg.decode_prefix
        self.param_bytes = cfg.param_count(
            active_only=(cfg.family == "moe")) * self._dtype_bytes()
        self._attn_layers = self._n_attn_layers()
        self._step_s = None         # computed lazily, cached (pure shapes)

    def _dtype_bytes(self) -> int:
        return 2 if "16" in self.cfg.dtype else 4

    def _n_attn_layers(self) -> int:
        fam = self.cfg.family
        if fam == "ssm":
            return 0
        if fam == "hybrid":
            return self.cfg.n_layers // max(self.cfg.attn_every, 1)
        if fam == "audio":
            return 2 * self.cfg.n_layers    # decoder self + cross attention
        return self.cfg.n_layers

    def decode_bytes_per_step(self, s: int | None = None) -> float:
        """Bytes one full-slot-table decode step moves (UPD cost channel).

        ``s`` is the cache fill to charge attention reads at; defaults to
        the slot table's max_len (steady-state worst case, reported to
        operators). Admission charges each request at ITS OWN maximal fill
        so a short request in a large slot table is not over-billed."""
        cfg = self.cfg
        s_eff = self.max_len if s is None else s

        def per_layer(s_: int) -> float:
            shapes = dict(B=self.batch, H=cfg.n_heads, KH=cfg.n_kv_heads,
                          S=s_, D=cfg.hd)
            try:
                from repro.tsl_api import cost
                raw = cost("attention_decode", "bytes", **shapes)
            except KeyError:
                # same formula as the UPD term, evaluated analytically
                raw = 2.0 * shapes["B"] * (
                    2 * shapes["KH"] * shapes["S"] + 2 * shapes["H"]
                ) * shapes["D"]
            # UPD bytes formulas follow the bf16 production convention
            # (2 B/elem); rescale so this term and param_bytes use the SAME
            # element size when the serving dtype differs (reduced = f32)
            return raw * (self._dtype_bytes() / 2.0)

        attn = 0.0
        if self._attn_layers:
            if cfg.family == "audio":
                # decoder self-attn reads the rolling cache; cross-attn reads
                # the FIXED enc_len-sized K/V, not max_len
                enc = self.enc_len if self.enc_len is not None else s_eff
                attn = cfg.n_layers * (per_layer(s_eff) + per_layer(enc))
            else:
                attn = self._attn_layers * per_layer(s_eff)
        return self.param_bytes + attn

    def step_seconds(self, s: int | None = None) -> float:
        if s is not None:
            return self.decode_bytes_per_step(s) / HBM_BW
        if self._step_s is None:
            self._step_s = self.decode_bytes_per_step() / HBM_BW
        return self._step_s

    def prefill_seconds(self, prompt_len: int) -> float:
        n = self.cfg.param_count(active_only=(self.cfg.family == "moe"))
        return 2.0 * n * prompt_len / PEAK_FLOPS

    def admit(self, req: Request, now_s: float) -> tuple[bool, str]:
        if self.prefix + req.prompt_len + req.gen_len > self.max_len:
            return False, (f"over_budget: prompt {req.prompt_len} + gen "
                           f"{req.gen_len}"
                           + (f" + vision prefix {self.prefix}"
                              if self.prefix else "")
                           + f" > max_len {self.max_len}")
        if req.sla_s is not None:
            waited = max(0.0, now_s - req.arrival_s)
            # charge attention reads at THIS request's maximal cache fill,
            # not max_len: a short request in a large slot table must not be
            # refused on traffic it will never generate
            s_req = self.prefix + req.prompt_len + req.gen_len
            projected = (waited + self.prefill_seconds(req.prompt_len)
                         + req.gen_len * self.step_seconds(s_req))
            if projected > req.sla_s:
                return False, (f"sla_infeasible: projected {projected:.3e}s "
                               f"> sla {req.sla_s:.3e}s")
        return True, "ok"


@dataclass
class _Slot:
    request: Request | None = None
    metrics: RequestMetrics | None = None
    served: int = 0                 # lifetime requests this slot carried

    @property
    def free(self) -> bool:
        return self.request is None


class Scheduler:
    """Request queue + slot table + SLA accounting.

    Protocol (driven by the engine once per decode step):
      submit(req, now)                 — enqueue (stamps arrival)
      next_admissible(now)             — pop the next request that passes
                                         admission; refused requests are
                                         recorded and dropped
      place(req, slot, step)           — occupy a slot (prefill done)
      first_token(slot, now)           — TTFT stamp
      step_done(slot)                  — one real token decoded in this slot
      finish(slot, now) -> metrics     — request complete, slot freed
    """

    def __init__(self, n_slots: int, admission: CostModelAdmission | None = None):
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: deque[Request] = deque()
        self.admission = admission
        self.finished: list[RequestMetrics] = []
        self.refused: list[Refusal] = []
        self.admission_log: list[dict] = []   # {step, slot, rid} per admission

    # -- queue ----------------------------------------------------------------

    def submit(self, req: Request, now_s: float) -> None:
        req.arrival_s = now_s
        self.queue.append(req)

    def next_admissible(self, now_s: float) -> Request | None:
        while self.queue:
            req = self.queue.popleft()
            if self.admission is None:
                return req
            ok, reason = self.admission.admit(req, now_s)
            if ok:
                return req
            self.refused.append(Refusal(req.rid, reason))
        return None

    # -- slot lifecycle -------------------------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    def place(self, req: Request, slot: int, step: int) -> None:
        s = self.slots[slot]
        if not s.free:
            raise ValueError(
                f"slot {slot} is occupied by {s.request.rid!r}")
        s.request = req
        s.served += 1
        s.metrics = RequestMetrics(
            rid=req.rid, slot=slot, prompt_len=req.prompt_len,
            gen_len=req.gen_len, sla_s=req.sla_s, admitted_at_step=step)
        self.admission_log.append({"step": step, "slot": slot, "rid": req.rid})

    def first_token(self, slot: int, now_s: float) -> None:
        m = self.slots[slot].metrics
        m.ttft_s = max(now_s - self.slots[slot].request.arrival_s, 1e-9)
        m.tokens_out = 1

    def step_done(self, slot: int) -> None:
        self.slots[slot].metrics.tokens_out += 1

    def slot_done(self, slot: int) -> bool:
        s = self.slots[slot]
        return (not s.free) and s.metrics.tokens_out >= s.request.gen_len

    def finish(self, slot: int, now_s: float) -> RequestMetrics:
        s = self.slots[slot]
        m, req = s.metrics, s.request
        m.latency_s = max(now_s - req.arrival_s, 1e-9)
        decode_s = max(m.latency_s - m.ttft_s, 1e-9)
        m.decode_tokens_per_s = max(m.tokens_out - 1, 0) / decode_s
        if m.sla_s is not None:
            m.sla_met = m.latency_s <= m.sla_s
        s.request, s.metrics = None, None
        self.finished.append(m)
        return m

    # -- aggregate view -------------------------------------------------------

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active_slots())

    def sla_hit_rate(self) -> float | None:
        scored = [m for m in self.finished if m.sla_met is not None]
        if not scored:
            return None
        return sum(m.sla_met for m in scored) / len(scored)

    def slot_reuse(self) -> list[int]:
        return [s.served for s in self.slots]
