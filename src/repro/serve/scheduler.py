"""Slot-table scheduler for per-step continuous batching.

Pure host-side control plane — no jax in here. The engine owns the device
state; the scheduler owns the request stream (arrival-gated queue), the
per-slot lifecycle (free -> reserved-for-prefill -> occupied -> free),
per-request SLA/deadline accounting, and the admission decision. Admission is
roofline-informed: the cost model consumes the SAME analytic ``lib.cost()``
terms the generator selected the primitive implementations with (PAPER.md
§cost channel), so "can this request meet its deadline on this hardware at
this batch size" is answered from the UPD cost formulas + the v5e roofline
constants, not from guesswork.

Arrivals are asynchronous: ``submit()`` may be called with a future
``arrival_s`` (a trace) or at any wall moment (a live caller); a request
becomes visible to admission only once ``now >= arrival_s``, and every
latency metric is measured from that arrival.

Prompts are length-bucketed before admission (:class:`BucketPolicy`): each
prompt is padded to the smallest UPD-declared bucket size, so the engine only
ever runs prefill shapes from a small declared set — the ARM-SVE
vector-length-agnostic discipline applied to serving. Bucket sizes and the
prefill chunk size are UPD data (``attention_prefill_chunk``'s ``serve:``
block in ``tsl_data/primitives/seq.yaml``), not engine constants.

Refusals are permanent and carry a reason (``over_budget`` — the request's
BUCKET does not fit the slot table's max_len or exceeds the largest declared
bucket; ``sla_infeasible`` — even the best-case estimate misses its
deadline), so callers can re-shape and resubmit rather than letting a doomed
request occupy a slot.
"""

from __future__ import annotations

import heapq
import logging
from collections import deque
from dataclasses import dataclass

from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

log = logging.getLogger(__name__)


def _mesh_dp_tp(mesh) -> tuple[int, int]:
    """(data-parallel, tensor-parallel) sizes of a mesh — shape-only
    introspection (``axis_names`` + device-array shape), so it works on
    ``jax.sharding.Mesh`` AND the shape-only fakes tests use, and keeps
    this module jax-free."""
    if mesh is None:
        return 1, 1
    import numpy as np
    sizes = dict(zip(tuple(mesh.axis_names), tuple(np.shape(mesh.devices))))
    tp = max(1, sizes.get("model", 1))
    dp = 1
    for name, size in sizes.items():
        if name != "model":
            dp *= max(1, size)
    return dp, tp

# (primitive, term) pairs already warned about — the analytic fallback fires
# once per step otherwise and would flood the serving logs
_warned_cost_terms: set[tuple[str, str]] = set()


def _cost_fallback_warn(primitive: str, term: str) -> None:
    """A generated package missing a priced cost term is a corpus defect
    (TSL-Check flags it statically as TSL014); warn ONCE per (primitive,
    term) so the silent analytic fallback is attributable in logs. The
    ``comms`` term gets its own wording: it prices MESH collective traffic,
    so a gap there mis-prices sharded serving specifically — distinct from a
    missing ``flops``/``bytes`` term, which mis-prices single-device
    roofline admission."""
    key = (primitive, term)
    if key in _warned_cost_terms:
        return
    _warned_cost_terms.add(key)
    if term == "comms":
        log.warning(
            "TSL014: generated library has no 'comms' cost term on %r — "
            "mesh-sharded admission prices per-step collective bytes from "
            "the analytic ring model instead (run `python -m repro.core "
            "analyze` to lint the UPD cost channel)", primitive)
    else:
        log.warning(
            "TSL014: generated library has no cost term %r/%r — admission "
            "falls back to the analytic formula (run `python -m repro.core "
            "analyze` to lint the UPD cost channel)", primitive, term)

# fallbacks when the UPD corpus is unavailable (mirrors the serve: block on
# the attention_prefill_chunk primitive)
DEFAULT_PREFILL_CHUNK = 8
DEFAULT_BUCKETS = (8, 16, 32, 64, 128, 256, 512)


def upd_serve_defaults() -> dict:
    """The ``serve:`` block declared on the attention_prefill_chunk
    primitive: {"chunk": int, "buckets": [int, ...]}. Falls back to module
    defaults if the corpus (or the block) is missing — the serving path must
    not die because a slimmed UPD dropped one primitive."""
    try:
        from repro.core import load_corpus

        extra = load_corpus().primitives["attention_prefill_chunk"].extra
        blk = dict(extra["serve"])
        return {"chunk": int(blk["chunk"]),
                "buckets": tuple(int(b) for b in blk["buckets"])}
    except Exception:
        return {"chunk": DEFAULT_PREFILL_CHUNK, "buckets": DEFAULT_BUCKETS}


class BucketPolicy:
    """Pad each prompt to the smallest declared bucket size.

    Buckets must be sorted, unique, positive multiples of the prefill chunk
    size — so every padded prompt decomposes into an exact number of
    fixed-shape chunk steps (``bucket // chunk``), and the engine's compiled
    prefill shapes are bounded by the declared set.
    """

    def __init__(self, buckets, chunk: int):
        buckets = tuple(int(b) for b in buckets)
        if not buckets or chunk < 1:
            raise ValueError("need at least one bucket and chunk >= 1")
        if list(buckets) != sorted(set(buckets)):
            raise ValueError(f"buckets must be sorted and unique: {buckets}")
        bad = [b for b in buckets if b <= 0 or b % chunk]
        if bad:
            raise ValueError(
                f"buckets must be positive multiples of chunk={chunk}: {bad}")
        self.buckets = buckets
        self.chunk = int(chunk)

    @classmethod
    def from_upd(cls, chunk: int | None = None,
                 buckets=None) -> "BucketPolicy":
        """Policy from the UPD serve block. A caller-chosen ``chunk`` that
        does not divide the declared buckets rounds each bucket UP to the
        next chunk multiple (deduplicated) — the declared sizes are the
        admissible prompt lengths, the executed schedule stays whole
        chunks."""
        d = upd_serve_defaults()
        chunk = int(chunk if chunk is not None else d["chunk"])
        cand = buckets if buckets is not None else d["buckets"]
        rounded = sorted({cls.round_up(b, chunk) for b in cand})
        return cls(rounded, chunk)

    @staticmethod
    def round_up(n: int, chunk: int) -> int:
        """Smallest multiple of ``chunk`` >= n (the synthetic bucket for
        out-of-policy prompt lengths)."""
        return -(-int(n) // int(chunk)) * int(chunk)

    def assign(self, prompt_len: int) -> int | None:
        """Smallest bucket >= prompt_len, or None if none fits."""
        for b in self.buckets:
            if prompt_len <= b:
                return b
        return None

    def n_chunks(self, bucket: int) -> int:
        return bucket // self.chunk


@dataclass
class Request:
    """One serving request: a prompt, a generation budget, an optional SLA.

    ``sla_s`` is an end-to-end latency deadline in seconds, measured from
    ``arrival_s`` — both admission (projection) and the final hit/miss
    accounting are against it. ``arrival_s`` may be preset to a FUTURE
    engine-clock time (trace-driven arrivals: the request stays invisible to
    admission until then); when left at 0.0 ``submit`` stamps it with the
    submission moment.
    """

    rid: str
    tokens: object                  # prompt token array (1-D, int)
    gen_len: int
    sla_s: float | None = None
    embeds: object | None = None    # per-request media: vlm (prefix, D)
                                    # vision / audio (enc_len, D) frames
    arrival_s: float = 0.0          # preset (trace) or stamped by submit
    bucket: int = 0                 # stamped at admission (BucketPolicy)
    temperature: float | None = None  # per-request override of the engine's
                                      # SamplingConfig (<=0 -> greedy); mixed
                                      # greedy/sampled slots coexist in one
                                      # batched step / verify span
    shared_prefix_len: int | None = None  # paged serving: leading tokens
                                      # shared across requests (a system
                                      # prompt) — the prefix-store boundary
                                      # hint; None lets the engine share the
                                      # whole prompt minus its last token
    resume_token: int | None = None   # paged serving: set on a PREEMPTED
                                      # continuation — the already-emitted
                                      # pending token the engine must resume
                                      # with instead of sampling a first one

    @property
    def prompt_len(self) -> int:
        return int(len(self.tokens))


@dataclass
class RequestMetrics:
    """Per-request accounting the engine reports (and tests assert on)."""

    rid: str
    slot: int = -1
    prompt_len: int = 0
    gen_len: int = 0
    bucket: int = 0                 # padded prompt length (length bucketing)
    tokens_out: int = 0
    ttft_s: float = 0.0             # arrival -> first token (queue + prefill)
    prefill_s: float = 0.0          # step time attributed to prefill chunks
    decode_s: float = 0.0           # step time attributed to decode tokens
    decode_tokens_per_s: float = 0.0
    latency_s: float = 0.0          # arrival -> last token
    sla_s: float | None = None
    sla_met: bool | None = None     # None: no SLA attached
    admitted_at_step: int = -1      # engine step index at slot reservation
    # speculative decoding: tokens_out / decode_tokens_per_s count ONLY
    # target-model-emitted tokens (accepted drafts + the corrected token);
    # rejected drafts are never billed as output
    spec_proposed: int = 0          # draft tokens proposed for this request
    spec_accepted: int = 0          # draft tokens accepted by the target
    verify_rounds: int = 0          # verify steps this request took part in
    preemptions: int = 0            # paged serving: times this request was
                                    # preempted on page exhaustion and
                                    # requeued as a continuation


@dataclass
class Refusal:
    rid: str
    reason: str


class CostModelAdmission:
    """Roofline admission driven by the generated library's cost channel.

    A decode step over the full slot table is modeled as memory-bound:
      bytes/step = param bytes (weights stream once per token)
                 + n_attn_layers x lib.cost("attention_decode", "bytes", ...)
      step_s     = bytes / HBM_BW
    Prefill is modeled as compute-bound and priced at the request's BUCKET
    (the padded length actually executed), parameter flops plus the
    ``attention_prefill_chunk`` UPD cost term summed over the chunk schedule.

    Both are deliberately idealized (roofline = best case); a request whose
    deadline fails even the BEST case is hopeless, which makes refusal sound.
    ``lib.cost`` raising KeyError (a generated package without the term) falls
    back to the same formula evaluated analytically — warning once per
    (primitive, term) with finding code TSL014, so the gap is attributable in
    logs and statically catchable (`python -m repro.core analyze`) instead of
    silently mispricing admission.
    """

    def __init__(self, cfg, batch: int, max_len: int,
                 enc_len: int | None = None,
                 policy: BucketPolicy | None = None,
                 mesh=None):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.enc_len = enc_len          # audio: fixed cross K/V length
        self.policy = policy            # None -> exact-length admission
        self.prefix = cfg.decode_prefix
        self.param_bytes = cfg.param_count(
            active_only=(cfg.family == "moe")) * self._dtype_bytes()
        self._attn_layers = self._n_attn_layers()
        self._step_s = None         # computed lazily, cached (pure shapes)
        # mesh-aware pricing: params and slot state are sharded over every
        # device (dist.sharding rules), so HBM traffic divides by the total
        # shard count, while the TP axis adds per-layer collective bytes
        # priced by the UPD ``comms`` term against the interconnect roofline
        self.mesh = mesh
        self.dp, self.tp = _mesh_dp_tp(mesh)
        self.shards = self.dp * self.tp
        # speculative decoding: the engine sets spec_k > 0 when a drafter is
        # attached; admission then prices decode at the BEST-CASE emitted
        # tokens per second across plain decode and a fully-accepted verify
        # span (roofline admission stays best-case, so refusal stays sound)
        self.spec_k = 0

    def _dtype_bytes(self) -> int:
        return 2 if "16" in self.cfg.dtype else 4

    def _n_attn_layers(self) -> int:
        fam = self.cfg.family
        if fam == "ssm":
            return 0
        if fam == "hybrid":
            return self.cfg.n_layers // max(self.cfg.attn_every, 1)
        if fam == "audio":
            return 2 * self.cfg.n_layers    # decoder self + cross attention
        return self.cfg.n_layers

    def decode_bytes_per_step(self, s: int | None = None) -> float:
        """Bytes one full-slot-table decode step moves (UPD cost channel).

        ``s`` is the cache fill to charge attention reads at; defaults to
        the slot table's max_len (steady-state worst case, reported to
        operators). Admission charges each request at ITS OWN maximal fill
        so a short request in a large slot table is not over-billed."""
        cfg = self.cfg
        s_eff = self.max_len if s is None else s

        def per_layer(s_: int) -> float:
            shapes = dict(B=self.batch, H=cfg.n_heads, KH=cfg.n_kv_heads,
                          S=s_, D=cfg.hd)
            try:
                from repro.tsl_api import cost
                raw = cost("attention_decode", "bytes", **shapes)
            except KeyError:
                _cost_fallback_warn("attention_decode", "bytes")
                # same formula as the UPD term, evaluated analytically
                raw = 2.0 * shapes["B"] * (
                    2 * shapes["KH"] * shapes["S"] + 2 * shapes["H"]
                ) * shapes["D"]
            # UPD bytes formulas follow the bf16 production convention
            # (2 B/elem); rescale so this term and param_bytes use the SAME
            # element size when the serving dtype differs (reduced = f32)
            return raw * (self._dtype_bytes() / 2.0)

        attn = 0.0
        if self._attn_layers:
            if cfg.family == "audio":
                # decoder self-attn reads the rolling cache; cross-attn reads
                # the FIXED enc_len-sized K/V, not max_len
                enc = self.enc_len if self.enc_len is not None else s_eff
                attn = cfg.n_layers * (per_layer(s_eff) + per_layer(enc))
            else:
                attn = self._attn_layers * per_layer(s_eff)
        return self.param_bytes + attn

    def _comms_term(self, primitive: str, fallback: float, **shapes) -> float:
        """One layer's collective bytes from the UPD ``comms`` term (TSL014
        analytic-ring fallback when the generated package lacks it).
        ``comms`` formulas follow the same bf16 wire convention as ``bytes``;
        rescale to the serving dtype."""
        shapes = dict(shapes, TP=self.tp)
        try:
            from repro.tsl_api import cost
            raw = cost(primitive, "comms", **shapes)
        except KeyError:
            _cost_fallback_warn(primitive, "comms")
            raw = fallback * (self.tp - 1) / self.tp
        return raw * (self._dtype_bytes() / 2.0)

    def comms_bytes_per_step(self, s: int | None = None) -> float:
        """Collective bytes ONE decode step moves over the TP axis: a ring
        all-reduce of each layer's output activations, priced by the new
        ``comms`` UPD cost term per layer family (attention_decode /
        ssd_scan / wkv6_scan). Zero off-mesh and on a TP=1 mesh — the
        (TP-1)/TP ring factor vanishes."""
        if self.tp <= 1:
            return 0.0
        cfg = self.cfg
        s_eff = self.max_len if s is None else s
        b, h, d = self.batch, cfg.n_heads, cfg.hd
        total = 0.0
        if self._attn_layers:
            attn = self._comms_term(
                "attention_decode", 4.0 * b * h * d,
                B=b, H=h, KH=cfg.n_kv_heads, S=s_eff, D=d)
            factor = cfg.n_layers * 2 if cfg.family == "audio" \
                else self._attn_layers
            total += factor * attn
        if cfg.family == "ssm":
            kk = cfg.rwkv_head_dim
            hh = cfg.d_model // max(kk, 1)
            total += cfg.n_layers * self._comms_term(
                "wkv6_scan", 4.0 * b * hh * kk,
                B=b, T=1, H=hh, K=kk, V=kk)
        elif cfg.family == "hybrid":
            p = cfg.ssm_head_dim
            hh = (cfg.d_inner_mult * cfg.d_model) // max(p, 1)
            scan_layers = cfg.n_layers - self._attn_layers
            total += scan_layers * self._comms_term(
                "ssd_scan", 4.0 * b * hh * p,
                B=b, T=1, H=hh, P=p, N=cfg.ssm_state)
        return total

    def step_seconds(self, s: int | None = None) -> float:
        if s is not None:
            return (self.decode_bytes_per_step(s) / (self.shards * HBM_BW)
                    + self.comms_bytes_per_step(s) / ICI_BW)
        if self._step_s is None:
            self._step_s = (
                self.decode_bytes_per_step() / (self.shards * HBM_BW)
                + self.comms_bytes_per_step() / ICI_BW)
        return self._step_s

    def verify_seconds(self, k: int, s: int | None = None) -> float:
        """Best-case time of ONE ragged verify step at draft depth k (span
        SV = k+1) over the full slot table: memory-bound like decode —
        param bytes stream once regardless of span width, plus the
        ``attention_verify`` UPD bytes term per attention layer. Recurrent
        and hybrid families pay the commit replay (the accepted prefix runs
        through the chunked-prefill path), modeled as a factor of 2. This is
        the price the SpeculationPolicy weighs against expected accepted
        tokens when choosing a per-slot depth."""
        cfg = self.cfg
        sv = int(k) + 1
        s_eff = self.max_len if s is None else s

        def per_layer(s_: int) -> float:
            shapes = dict(B=self.batch, H=cfg.n_heads, KH=cfg.n_kv_heads,
                          SV=sv, S=s_, D=cfg.hd)
            try:
                from repro.tsl_api import cost
                raw = cost("attention_verify", "bytes", **shapes)
            except KeyError:
                _cost_fallback_warn("attention_verify", "bytes")
                raw = 2.0 * shapes["B"] * (
                    2 * shapes["KH"] * shapes["S"] + 2 * shapes["H"] * sv
                ) * shapes["D"]
            return raw * (self._dtype_bytes() / 2.0)

        attn = 0.0
        if self._attn_layers:
            if cfg.family == "audio":
                enc = self.enc_len if self.enc_len is not None else s_eff
                attn = cfg.n_layers * (per_layer(s_eff) + per_layer(enc))
            else:
                attn = self._attn_layers * per_layer(s_eff)
        commit_factor = 2.0 if cfg.family in ("ssm", "hybrid") else 1.0
        comms_s = 0.0
        if self.tp > 1 and self._attn_layers:
            comms = self._comms_term(
                "attention_verify", 4.0 * self.batch * cfg.n_heads * sv * cfg.hd,
                B=self.batch, H=cfg.n_heads, KH=cfg.n_kv_heads,
                SV=sv, S=s_eff, D=cfg.hd)
            factor = cfg.n_layers * 2 if cfg.family == "audio" \
                else self._attn_layers
            comms_s = factor * comms / ICI_BW
        return ((self.param_bytes + attn) / (self.shards * HBM_BW)
                + comms_s) * commit_factor

    def emit_seconds_per_token(self, s: int | None = None) -> float:
        """Best-case seconds per EMITTED token: plain decode, or — when the
        engine runs speculation — a fully-accepted verify span at spec_k
        (k+1 tokens per step), whichever is cheaper."""
        per_tok = self.step_seconds(s)
        if self.spec_k > 0:
            per_tok = min(per_tok,
                          self.verify_seconds(self.spec_k, s)
                          / (self.spec_k + 1))
        return per_tok

    def prefill_seconds(self, padded_len: int) -> float:
        """Best-case prefill time for ``padded_len`` prompt tokens: parameter
        flops + the attention_prefill_chunk cost term summed over the chunk
        schedule (each chunk priced at its own growing cache fill)."""
        cfg = self.cfg
        n = cfg.param_count(active_only=(cfg.family == "moe"))
        flops = 2.0 * n * padded_len
        if self._attn_layers:
            chunk = self.policy.chunk if self.policy else padded_len
            fills = range(chunk, padded_len + 1, chunk) if chunk else ()

            def chunk_flops(fill: int) -> float:
                shapes = dict(B=1, H=cfg.n_heads, KH=cfg.n_kv_heads,
                              C=chunk, S=self.prefix + fill, D=cfg.hd)
                try:
                    from repro.tsl_api import cost
                    return cost("attention_prefill_chunk", "flops", **shapes)
                except KeyError:
                    _cost_fallback_warn("attention_prefill_chunk", "flops")
                    return 4.0 * shapes["H"] * shapes["C"] * shapes["S"] \
                        * shapes["D"]

            flops += self._attn_layers * sum(chunk_flops(f) for f in fills)
        seconds = flops / (self.shards * PEAK_FLOPS)
        if self.tp > 1 and self._attn_layers:
            chunk = self.policy.chunk if self.policy else padded_len
            n_chunks = padded_len // chunk if chunk else 0
            comms = self._comms_term(
                "attention_prefill_chunk",
                4.0 * chunk * cfg.n_heads * cfg.hd,
                B=1, H=cfg.n_heads, KH=cfg.n_kv_heads, C=chunk,
                S=self.prefix + padded_len, D=cfg.hd)
            seconds += self._attn_layers * n_chunks * comms / ICI_BW
        return seconds

    def mesh_info(self) -> dict | None:
        """Mesh pricing summary for the engine report (None off-mesh):
        axis sizes, the per-shard parameter bytes the roofline divides to,
        and the UPD-priced collective bytes per full-table decode step."""
        if self.mesh is None:
            return None
        return {
            "axes": {"data": self.dp, "model": self.tp},
            "shards": self.shards,
            "param_bytes_per_shard": self.param_bytes / self.shards,
            "comms_bytes_per_step": self.comms_bytes_per_step(),
            "step_seconds": self.step_seconds(),
        }

    def admit(self, req: Request, now_s: float) -> tuple[bool, str]:
        if self.policy is not None:
            bucket = self.policy.assign(req.prompt_len)
            if bucket is None:
                return False, (f"over_budget: prompt {req.prompt_len} exceeds "
                               f"largest bucket {self.policy.buckets[-1]}")
        else:
            bucket = req.prompt_len
        if self.prefix + bucket + req.gen_len > self.max_len:
            return False, (f"over_budget: bucket {bucket} (prompt "
                           f"{req.prompt_len}) + gen {req.gen_len}"
                           + (f" + vision prefix {self.prefix}"
                              if self.prefix else "")
                           + f" > max_len {self.max_len}")
        if req.sla_s is not None:
            waited = max(0.0, now_s - req.arrival_s)
            # charge attention reads at THIS request's maximal cache fill,
            # not max_len: a short request in a large slot table must not be
            # refused on traffic it will never generate
            s_req = self.prefix + bucket + req.gen_len
            projected = (waited + self.prefill_seconds(bucket)
                         + req.gen_len * self.emit_seconds_per_token(s_req))
            if projected > req.sla_s:
                return False, (f"sla_infeasible: projected {projected:.3e}s "
                               f"> sla {req.sla_s:.3e}s")
        req.bucket = bucket
        return True, "ok"


class PagedAdmission(CostModelAdmission):
    """Page-count admission for the paged slot store: admit on pages
    available NOW, not on worst-case bucket bytes.

    The contiguous admission implicitly prices every request at a full
    max-bucket cache reservation (a lane IS that reservation). With paged
    memory the honest price is the pages the request's PROMPT needs at
    attach (decode growth is paid step by step, with preemption as the
    backstop), against the pages allocatable right now — the free list, every
    evictable prefix-store page, AND every host-spillable page (cold unpinned
    requests' exclusive pages: the spill tier evicts them to host RAM on
    demand and rehydrates on next touch, so they are reclaimable without
    losing the request). ``budget`` is any object with
    ``pages_for_rows(rows)`` and ``pages_free()`` (the
    :class:`repro.serve.paging.PagedKVStore` interface; tests inject fakes).

    A page shortage is TRANSIENT (decodes finish, pages free), so it defers
    rather than refuses: the ``defer:`` reason prefix makes
    ``Scheduler.next_admissible`` put the request back at the FRONT of the
    queue instead of recording a permanent refusal. Lane-capacity and SLA
    refusals from the base class stay permanent.

    A preempted continuation (``resume_token`` set) skips the SLA and
    gen-budget re-checks — the original admission already priced the full
    request, and refusing a half-served request would lose emitted tokens —
    but still pays the page check for its (longer) re-prefill prompt.
    """

    def __init__(self, cfg, batch: int, max_len: int, *, budget,
                 enc_len: int | None = None,
                 policy: BucketPolicy | None = None,
                 mesh=None):
        super().__init__(cfg, batch, max_len, enc_len=enc_len, policy=policy,
                         mesh=mesh)
        self.budget = budget

    def mesh_info(self) -> dict | None:
        """Page budgets divide by the shard count too: every pool leaf is
        itself sharded over the mesh, so one LOGICAL page costs
         1/shards of its bytes on each device — reported per shard so
        operators see the budget each device actually holds."""
        info = super().mesh_info()
        if info is None:
            return None
        n_pages = getattr(self.budget, "n_pages", None)
        page_bytes = getattr(self.budget, "page_bytes", None)
        if n_pages is not None and page_bytes is not None:
            info["page_budget_bytes_per_shard"] = \
                n_pages * page_bytes / self.shards
        return info

    def admit(self, req: Request, now_s: float) -> tuple[bool, str]:
        if req.resume_token is not None:
            chunk = self.policy.chunk if self.policy else 1
            bucket = (self.policy.assign(req.prompt_len)
                      if self.policy else None) \
                or BucketPolicy.round_up(req.prompt_len, chunk)
            if self.prefix + bucket + 1 > self.max_len:
                return False, (f"over_budget: continuation prompt "
                               f"{req.prompt_len} cannot re-prefill within "
                               f"max_len {self.max_len}")
            req.bucket = bucket
        else:
            ok, reason = super().admit(req, now_s)
            if not ok:
                return ok, reason
        need = self.budget.pages_for_rows(self.prefix + req.prompt_len)
        free = self.budget.pages_free()
        if need > free:
            return False, (f"defer: needs {need} pages for its prompt, "
                           f"{free} allocatable now")
        return True, "ok"


@dataclass
class _Slot:
    request: Request | None = None     # occupied: decoding
    reserved: Request | None = None    # reserved: prefill chunks in flight
    metrics: RequestMetrics | None = None
    served: int = 0                    # lifetime requests this slot carried

    @property
    def free(self) -> bool:
        return self.request is None and self.reserved is None


class Scheduler:
    """Arrival-gated request stream + slot table + SLA accounting.

    Protocol (driven by the engine once per unified step):
      submit(req, now)                 — enqueue (future arrival_s -> pending)
      release(now)                     — move arrived requests into the queue
      next_admissible(now)             — pop the next request that passes
                                         admission; refused requests are
                                         recorded and dropped
      reserve(slot, req, step)         — slot enters prefill (chunks running)
      place(req, slot)                 — prefill done: slot occupied
      first_token(slot, now)           — TTFT stamp
      step_done(slot)                  — one real token decoded in this slot
      attribute_step_time(...)         — split a shared step's wall time
                                         between prefill and decode tokens
      finish(slot, now) -> metrics     — request complete, slot freed

    Paged mode adds a lane-less track: reserve_unplaced / place_parked /
    first_token_unplaced / finish_unplaced (admission and parking are
    page-count decisions, not lane decisions) and preempt / requeue_front
    (page exhaustion sends a decoding request back to the queue head as a
    resumable continuation).
    """

    def __init__(self, n_slots: int, admission: CostModelAdmission | None = None):
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: deque[Request] = deque()
        self.pending: list[tuple[float, int, Request]] = []   # arrival heap
        self._seq = 0
        self.admission = admission
        self.finished: list[RequestMetrics] = []
        self.refused: list[Refusal] = []
        self.admission_log: list[dict] = []   # {step, slot, rid} per admission
        # paged serving: requests admitted WITHOUT a lane (prefilling into a
        # donor, or parked resident in pages awaiting a free lane)
        self.unplaced: dict[str, tuple[Request, RequestMetrics]] = {}

    # -- request stream -------------------------------------------------------

    def submit(self, req: Request, now_s: float) -> None:
        """Async-safe ingestion: a request with a future ``arrival_s`` is
        held pending (invisible to admission) until the engine clock reaches
        it; a preset PAST arrival is honored (the wait since then counts
        toward TTFT/SLA); only an unset arrival (0.0) is stamped with the
        submission moment."""
        if req.arrival_s > now_s:
            heapq.heappush(self.pending, (req.arrival_s, self._seq, req))
            self._seq += 1
        else:
            if req.arrival_s <= 0.0:
                req.arrival_s = now_s
            self.queue.append(req)

    def release(self, now_s: float) -> int:
        """Move every pending request whose arrival time has come into the
        admission queue (arrival order). Returns how many arrived."""
        n = 0
        while self.pending and self.pending[0][0] <= now_s:
            _, _, req = heapq.heappop(self.pending)
            self.queue.append(req)
            n += 1
        return n

    def next_arrival_s(self) -> float | None:
        return self.pending[0][0] if self.pending else None

    def next_admissible(self, now_s: float) -> Request | None:
        while self.queue:
            req = self.queue.popleft()
            if self.admission is None:
                return req
            ok, reason = self.admission.admit(req, now_s)
            if ok:
                return req
            if reason.startswith("defer"):
                # transient shortage (paged admission: pages free up as
                # decodes finish): keep FIFO order, try again next step
                self.queue.appendleft(req)
                return None
            self.refused.append(Refusal(req.rid, reason))
        return None

    def requeue_front(self, req: Request) -> None:
        """Put a request at the HEAD of the queue: a preempted continuation
        (it was already being served — it must not wait behind arrivals) or
        an attach that lost a page race."""
        self.queue.appendleft(req)

    # -- slot lifecycle -------------------------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s.request is not None]

    def reserved_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s.reserved is not None]

    def reserve(self, slot: int, req: Request, step: int) -> None:
        s = self.slots[slot]
        if not s.free:
            raise ValueError(f"slot {slot} is not free")
        s.reserved = req
        self.admission_log.append({"step": step, "slot": slot, "rid": req.rid})
        s.metrics = RequestMetrics(
            rid=req.rid, slot=slot, prompt_len=req.prompt_len,
            gen_len=req.gen_len, bucket=req.bucket or req.prompt_len,
            sla_s=req.sla_s, admitted_at_step=step)

    def place(self, req: Request, slot: int, step: int | None = None) -> None:
        s = self.slots[slot]
        if s.reserved is None and s.request is None:
            # direct placement (no reserve phase: unit tests / legacy path)
            self.reserve(slot, req, -1 if step is None else step)
        elif s.reserved is not req and s.reserved is not None:
            raise ValueError(
                f"slot {slot} is reserved by {s.reserved.rid!r}")
        elif s.request is not None:
            raise ValueError(
                f"slot {slot} is occupied by {s.request.rid!r}")
        s.request = req
        s.reserved = None
        s.served += 1

    # -- paged serving: lane-less admission, parking, preemption --------------

    def reserve_unplaced(self, req: Request, step: int) -> RequestMetrics:
        """Admit a request WITHOUT reserving a lane (paged mode: the prefill
        runs in a donor, and a completed request may stay parked in pages).
        Logged with slot = -1; ``place_parked`` moves it into a lane later,
        carrying these metrics with it."""
        if req.rid in self.unplaced:
            raise ValueError(f"request {req.rid!r} already unplaced")
        self.admission_log.append({"step": step, "slot": -1, "rid": req.rid})
        m = RequestMetrics(
            rid=req.rid, slot=-1, prompt_len=req.prompt_len,
            gen_len=req.gen_len, bucket=req.bucket or req.prompt_len,
            sla_s=req.sla_s, admitted_at_step=step)
        self.unplaced[req.rid] = (req, m)
        return m

    def unplaced_metrics(self, rid: str) -> RequestMetrics:
        return self.unplaced[rid][1]

    def place_parked(self, rid: str, slot: int) -> Request:
        """Activate an unplaced (parked) request into a free lane, carrying
        its metrics (TTFT was stamped at prefill completion, while parked)."""
        s = self.slots[slot]
        if not s.free:
            raise ValueError(f"slot {slot} is not free")
        req, m = self.unplaced.pop(rid)
        m.slot = slot
        s.request, s.metrics = req, m
        s.served += 1
        return req

    def first_token_unplaced(self, rid: str, now_s: float) -> None:
        """TTFT stamp for a request finishing prefill without a lane: the
        first token exists (sampled from the last prefill chunk) whether or
        not a lane is free to decode the second one."""
        req, m = self.unplaced[rid]
        m.ttft_s = max(now_s - req.arrival_s, 1e-9)
        m.tokens_out = 1

    def finish_unplaced(self, rid: str, now_s: float) -> RequestMetrics:
        """Complete a request that never got (or no longer needs) a lane —
        the parked gen_len == 1 edge case."""
        req, m = self.unplaced.pop(rid)
        m.latency_s = max(now_s - req.arrival_s, 1e-9)
        decode_s = m.decode_s if m.decode_s > 0 \
            else max(m.latency_s - m.ttft_s, 1e-9)
        m.decode_tokens_per_s = max(m.tokens_out - 1, 0) / max(decode_s, 1e-9)
        if m.sla_s is not None:
            m.sla_met = m.latency_s <= m.sla_s
        self.finished.append(m)
        return m

    def preempt(self, slot: int) -> tuple[Request, RequestMetrics]:
        """Evict a DECODING request from its lane on page exhaustion. The
        request is NOT finished: the engine stashes the metrics, requeues a
        continuation (prompt + emitted tokens, ``resume_token`` set) at the
        queue head, and merges the accounting when the continuation
        completes its re-prefill."""
        s = self.slots[slot]
        if s.request is None:
            raise ValueError(f"slot {slot} is not decoding")
        req, m = s.request, s.metrics
        s.request, s.reserved, s.metrics = None, None, None
        return req, m

    def first_token(self, slot: int, now_s: float) -> None:
        m = self.slots[slot].metrics
        m.ttft_s = max(now_s - self.slots[slot].request.arrival_s, 1e-9)
        m.tokens_out = 1

    def step_done(self, slot: int, n: int = 1) -> None:
        """``n`` target-model-emitted tokens landed in this slot this step
        (n > 1: a verify round accepted n-1 drafts + the corrected token;
        rejected drafts are never counted)."""
        self.slots[slot].metrics.tokens_out += n

    def spec_round(self, slot: int, proposed: int, accepted: int) -> None:
        """Account one verify round for this slot's request."""
        m = self.slots[slot].metrics
        m.spec_proposed += proposed
        m.spec_accepted += accepted
        m.verify_rounds += 1

    def slot_done(self, slot: int) -> bool:
        s = self.slots[slot]
        return (s.request is not None
                and s.metrics.tokens_out >= s.request.gen_len)

    def attribute_step_time(self, t_step: float, prefill_tokens: int,
                            decode_slots: list[int],
                            decode_tokens: int | None = None
                            ) -> tuple[float, float]:
        """Split one shared step's wall time proportionally between the
        prefill tokens (chunk work) and decode tokens it processed
        (``decode_tokens`` defaults to one per active slot; a speculative
        verify round passes the EMITTED count — accepted + corrected — so
        the split tracks real output). The decode share is credited to EVERY
        decoding request's ``decode_s`` (wall time is shared, not divided —
        each request waited the full decode window); the prefill share is
        returned for the engine to credit the prefilling request(s).
        Without this split, a long prompt's chunks would silently inflate
        its neighbours' reported decode-t/s denominators."""
        if decode_tokens is None:
            decode_tokens = len(decode_slots)
        total = prefill_tokens + decode_tokens
        if total == 0 or t_step <= 0:
            return 0.0, 0.0
        pre_share = t_step * prefill_tokens / total
        dec_share = t_step - pre_share
        for slot in decode_slots:
            self.slots[slot].metrics.decode_s += dec_share
        return pre_share, dec_share

    def add_prefill_time(self, slot: int, seconds: float) -> None:
        if self.slots[slot].metrics is not None:
            self.slots[slot].metrics.prefill_s += seconds

    def finish(self, slot: int, now_s: float) -> RequestMetrics:
        s = self.slots[slot]
        m, req = s.metrics, s.request
        m.latency_s = max(now_s - req.arrival_s, 1e-9)
        # decode_s is attributed per shared step (prefill chunks excluded);
        # fall back to wall-minus-ttft when no attribution ran (unit tests)
        decode_s = m.decode_s if m.decode_s > 0 \
            else max(m.latency_s - m.ttft_s, 1e-9)
        m.decode_tokens_per_s = max(m.tokens_out - 1, 0) / max(decode_s, 1e-9)
        if m.sla_s is not None:
            m.sla_met = m.latency_s <= m.sla_s
        s.request, s.reserved, s.metrics = None, None, None
        self.finished.append(m)
        return m

    # -- aggregate view -------------------------------------------------------

    def has_work(self) -> bool:
        return (bool(self.queue) or bool(self.pending)
                or bool(self.active_slots()) or bool(self.reserved_slots())
                or bool(self.unplaced))

    def sla_hit_rate(self) -> float | None:
        scored = [m for m in self.finished if m.sla_met is not None]
        if not scored:
            return None
        return sum(m.sla_met for m in scored) / len(scored)

    def slot_reuse(self) -> list[int]:
        return [s.served for s in self.slots]
