"""Mesh-sharded serving tests (ISSUE 10 tentpole).

Two tiers:

* pure admission/pricing tests against a shape-only fake mesh — always run;
* token-for-token equivalence of the (data=2, model=4) engine vs the
  1-device engine, across all four decode families, greedy AND sampled,
  with per-step sharding asserted stable — these need 8 simulated devices
  (``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the CI
  multi-device lane) and skip elsewhere.
"""

import numpy as np
import pytest

from repro.configs import get_config
from repro.serve import (CostModelAdmission, PagedConfig, Request,
                         SamplingConfig, ServeEngine)
from repro.serve.scheduler import PagedAdmission


class _FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


def _mesh24():
    return _FakeMesh((2, 4), ("data", "model"))


# -- mesh-aware admission (no devices needed) ----------------------------------


def test_cost_admission_divides_roofline_by_shards():
    cfg = get_config("qwen1.5-0.5b").reduced()
    solo = CostModelAdmission(cfg, batch=4, max_len=64)
    mesh = CostModelAdmission(cfg, batch=4, max_len=64, mesh=_mesh24())
    assert (mesh.dp, mesh.tp, mesh.shards) == (2, 4, 8)
    # same logical bytes, divided over 8 shards — but the TP collectives add
    # interconnect time, so the step is faster yet NOT a clean 8x
    assert mesh.decode_bytes_per_step() == solo.decode_bytes_per_step()
    assert mesh.step_seconds() < solo.step_seconds()
    assert mesh.comms_bytes_per_step() > 0.0
    assert solo.comms_bytes_per_step() == 0.0        # tp=1: ring term vanishes


@pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-7b", "whisper-tiny"])
def test_comms_priced_for_every_family(arch):
    cfg = get_config(arch).reduced()
    adm = CostModelAdmission(cfg, batch=4, max_len=64,
                             enc_len=8 if cfg.family == "audio" else None,
                             mesh=_mesh24())
    assert adm.comms_bytes_per_step() > 0.0


def test_mesh_info_report_fields():
    cfg = get_config("qwen1.5-0.5b").reduced()
    adm = CostModelAdmission(cfg, batch=4, max_len=64, mesh=_mesh24())
    info = adm.mesh_info()
    assert info["axes"] == {"data": 2, "model": 4}
    assert info["shards"] == 8
    assert info["param_bytes_per_shard"] == adm.param_bytes / 8
    assert info["comms_bytes_per_step"] == adm.comms_bytes_per_step()
    off = CostModelAdmission(cfg, batch=4, max_len=64)
    assert off.mesh_info() is None


def test_paged_admission_divides_page_budget():
    class _Budget:
        n_pages = 16
        page_bytes = 4096

        def pages_for_rows(self, rows):
            return 1

    cfg = get_config("qwen1.5-0.5b").reduced()
    adm = PagedAdmission(cfg, batch=4, max_len=64, budget=_Budget(),
                         mesh=_mesh24())
    info = adm.mesh_info()
    assert info["page_budget_bytes_per_shard"] == 16 * 4096 / 8


# -- (2,4) mesh vs 1 device: token-for-token equivalence -----------------------

import jax  # noqa: E402  (device count must be read after jax init)

needs_8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(CI multi-device lane)")


def _mesh():
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("data", "model"))


def _run(arch, *, mesh=None, sampling=None, paged=None, batch=4, gen=5,
         shared_prefix=False):
    cfg = get_config(arch).reduced()
    enc_len = 8 if cfg.family == "audio" else None
    rng = np.random.default_rng(0)
    eng = ServeEngine(cfg, batch=batch, max_len=64, seed=0, mesh=mesh,
                      sampling=sampling, paged=paged, enc_len=enc_len)
    reqs = []
    for i in range(batch + 1):           # one more than lanes: slot reuse
        if shared_prefix:
            toks = np.array(list(range(1, 17)) + [30 + i], np.int32)
        else:
            toks = rng.integers(0, cfg.vocab, 8 + i).astype(np.int32)
        r = Request(rid=f"r{i}", tokens=toks, gen_len=gen)
        if enc_len is not None:
            r.embeds = (0.1 * (i + 1) *
                        np.ones((enc_len, cfg.d_model), np.float32))
        if sampling is not None and i == 0:
            r.temperature = 0.9          # per-request override rides along
        reqs.append(r)
    rep = eng.run(reqs)
    return {k: tuple(v) for k, v in rep["outputs"].items()}, rep


def _assert_equivalent(arch, **kw):
    base, _ = _run(arch, **kw)
    toks, rep = _run(arch, mesh=_mesh(), **kw)
    assert toks == base                     # token-for-token, every request
    # compiled once against rule-sharded donors: zero steady-state resharding
    assert rep["mesh"]["reshard_events"] == 0
    assert rep["mesh"]["axes"] == {"data": 2, "model": 4}
    assert rep["mesh"]["hbm_resident_bytes_per_shard"] > 0
    assert rep["mesh"]["comms_bytes_per_step"] > 0
    return rep


@needs_8
@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-7b", "zamba2-7b",
                                  "whisper-tiny"])
def test_mesh_greedy_equivalence_all_families(arch):
    _assert_equivalent(arch)


@needs_8
@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-7b"])
def test_mesh_sampled_equivalence(arch):
    """Sampled too: the partitionable threefry stream draws the same tokens
    whatever the logits' layout (mixed greedy/sampled slots included)."""
    _assert_equivalent(arch,
                       sampling=SamplingConfig(temperature=0.8, top_k=20))


@needs_8
def test_mesh_paged_fused_prefix_sharing_equivalence():
    rep = _assert_equivalent(
        "qwen1.5-0.5b", batch=2, shared_prefix=True,
        paged=PagedConfig(prefix_sharing=True, fused=True, page_size=8))
    assert rep["paged"]["prefix_hits"] >= 1
    assert "pricing" in rep["mesh"]
    assert rep["mesh"]["pricing"]["page_budget_bytes_per_shard"] > 0


@needs_8
def test_mesh_train_step_runs_sharded():
    """make_train_step(mesh=...) pins params AND float moments to the rules:
    one step on the (2,4) mesh matches the unmeshed step's loss and keeps
    every parameter leaf on its rule sharding."""
    import jax.numpy as jnp

    from repro.dist import sharding as dist_sharding
    from repro.nn.model import build_model
    from repro.train.optimizer import OptConfig
    from repro.train.step import init_train_state, make_train_step

    cfg = get_config("qwen1.5-0.5b").reduced()
    model = build_model(cfg)
    opt_cfg = OptConfig(lr=1e-3, total_steps=10, warmup_steps=2)
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jnp.zeros((4, 16), jnp.int32),
             "labels": jnp.zeros((4, 16), jnp.int32)}

    solo = jax.jit(make_train_step(model, opt_cfg))(
        init_train_state(model, opt_cfg, key), batch)
    mesh = _mesh()
    state = init_train_state(model, opt_cfg, key, mesh=mesh)
    step = jax.jit(make_train_step(model, opt_cfg, mesh=mesh),
                   donate_argnums=(0,))
    with mesh:
        new_state, metrics = step(state, batch)
    assert np.allclose(float(metrics["loss"]), float(solo[1]["loss"]),
                       rtol=1e-5)
    expected = dist_sharding.param_shardings(mesh, new_state["params"])
    for got, want in zip(jax.tree.leaves(new_state["params"]),
                         jax.tree.leaves(expected)):
        assert got.sharding.is_equivalent_to(want, got.ndim)
