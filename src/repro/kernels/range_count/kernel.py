"""Pallas TPU kernel: fused range-count (the paper's Fig 8 algorithm as ONE
kernel instead of a primitive chain — the beyond-paper fusion the generator's
data model can carry as a specialized variant).

The paper's SIMD loop (load -> between_inclusive -> mask->int -> add, then a
final hadd) becomes: grid over (rows/bm) VMEM tiles of a (rows, 128) view;
each step counts in-range lanes of its tile on the VPU and accumulates into a
lane-replicated SMEM-resident running count via an output block revisited at
every grid step (index_map constant), written once at the final step.

The finalization `hadd` of Fig 8/9 is the in-tile jnp.sum reduction — on TPU
the adder tree of Fig 11 is what the VPU cross-lane reduction emits anyway
(DESIGN.md §2: VREG 8x128 tiles replace 128-512 bit registers).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu



def _range_count_kernel(x_ref, lo_ref, hi_ref, o_ref, acc_scr, *, n_valid: int,
                        bm: int, lanes: int):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...]
    lo = lo_ref[0, 0]
    hi = hi_ref[0, 0]
    # global element index of each lane, to mask the tail padding
    row0 = i * bm
    pos = (row0 + jax.lax.broadcasted_iota(jnp.int32, (bm, lanes), 0)) * lanes \
        + jax.lax.broadcasted_iota(jnp.int32, (bm, lanes), 1)
    in_range = jnp.logical_and(x >= lo, x <= hi)
    in_range = jnp.logical_and(in_range, pos < n_valid)
    acc_scr[...] += jnp.sum(in_range.astype(jnp.int32), axis=0, keepdims=True)

    @pl.when(i == n - 1)
    def _finalize():
        o_ref[0, 0] = jnp.sum(acc_scr[...])


def range_count_2d(x2, low, high, *, n_valid: int, block_rows: int = 512,
                   interpret: bool = False):
    """x2: (rows, lanes) padded view; returns int32 scalar count."""
    rows, lanes = x2.shape
    bm = min(block_rows, rows)
    assert rows % bm == 0
    grid = (rows // bm,)
    lo = jnp.asarray(low, x2.dtype).reshape(1, 1)
    hi = jnp.asarray(high, x2.dtype).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_range_count_kernel, n_valid=n_valid, bm=bm,
                          lanes=lanes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, lanes), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="tsl_range_count",
    )(x2, lo, hi)
    return out[0, 0]
