"""Run the TSLGen-GENERATED test suites (paper §4.1) for both host-runnable
targets. Generated tests are topologically ordered by the dependency DAG;
executing them here makes the generated library a first-class tested artifact
of our own CI."""

import importlib


def _generated_tests(lib):
    mod = importlib.import_module(lib.__name__ + ".tests.test_generated")
    return [(name, getattr(mod, name)) for name in sorted(dir(mod))
            if name.startswith("test_")]


def test_cpu_xla_generated_suite(lib_cpu):
    tests = _generated_tests(lib_cpu)
    assert len(tests) > 100
    failures = []
    for name, fn in tests:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append(f"{name}: {e}")
    assert not failures, "\n".join(failures[:10])


def test_pallas_interpret_generated_suite(lib_interp):
    """The interpret target routes rmsnorm/flash_attention/swiglu/range_count
    through the Pallas kernels — this IS the per-kernel validation sweep at
    the generated-library level (paper: 'execution within an emulator')."""
    tests = _generated_tests(lib_interp)
    assert len(tests) > 100
    failures = []
    for name, fn in tests:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append(f"{name}: {e}")
    assert not failures, "\n".join(failures[:10])


def test_generated_test_order_respects_dag(lib_cpu):
    """Order in the generated file must topologically respect `requires`."""
    import re
    from pathlib import Path

    src = (Path(lib_cpu.__file__).parent / "tests" / "test_generated.py").read_text()
    order = []
    deps = {}
    for m in re.finditer(
            r"def test_(\w+?)__(\w+?)__(\w+)\(\):\n    \"\"\".*?deps=\[(.*?)\]",
            src, re.S):
        prim = m.group(1)
        if prim not in order:
            order.append(prim)
        req = [s.strip("' ") for s in m.group(4).split(",") if s.strip()]
        deps.setdefault(prim, set()).update(r for r in req if r)
    pos = {p: i for i, p in enumerate(order)}
    for prim, reqs in deps.items():
        for r in reqs:
            if r in pos:
                assert pos[r] < pos[prim], (r, prim)
