"""Pure-jnp oracle for row softmax (and the portable TSL implementation)."""

from __future__ import annotations

import jax.numpy as jnp


def softmax(x):
    xf = x.astype(jnp.float32)
    m = jnp.max(xf, axis=-1, keepdims=True)
    p = jnp.exp(xf - m)
    return (p / jnp.sum(p, axis=-1, keepdims=True)).astype(x.dtype)
