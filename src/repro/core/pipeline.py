"""GPO pipelines (paper Fig 5 ①).

*"We designed our generator core as a pipeline consisting of multiple
generator pipeline operators (GPO), where every GPO depends on the result of
the previous one. That way, the GPOs remain exchangeable, and the pipeline can
be altered in its behavior by changing an operator or expanded by adding
further operators."*

Since the incremental-engine refactor the GPOs are split into two phases:

* **corpus phase** (``corpus.CorpusPipeline``): template-check + validate,
  target-agnostic, run ONCE per UPD fingerprint, producing an immutable
  :class:`~.model.CorpusIR`.
* **target phase** (:class:`Pipeline` here): select → [bench-select] →
  generate → testgen/buildgen/docgen, run once per (target, config) on a
  shared corpus, producing a :class:`~.model.GenerationResult`.
"""

from __future__ import annotations

from typing import Protocol

from . import engine
from .model import GenConfig, GenerationResult


class GPO(Protocol):
    name: str

    def run(self, ctx): ...


class GenerationError(RuntimeError):
    def __init__(self, errors: list[str], warnings: list[str]):
        self.errors = errors
        self.warnings = warnings
        super().__init__(
            "TSLGen pipeline failed:\n" + "\n".join(f"  error: {e}" for e in errors)
        )


class TemplateCheckGPO:
    """Paper ①: 'every code template is loaded once into the framework and
    subsequently validated' — Jinja2 syntax errors surface here, not mid-render.
    Corpus-phase GPO: templates are target-agnostic, so one check covers every
    generation target."""

    name = "template-check"

    def run(self, ctx):
        env = engine.environment()
        for name in env.list_templates(filter_func=lambda n: n.endswith(".j2")):
            try:
                env.get_template(name)
            except Exception as e:  # pragma: no cover - template bugs
                ctx.fail(f"template {name!r}: {e}")
        return ctx


class OperatorList:
    """Exchangeability / extension port shared by both pipeline phases
    (paper Fig 5 ⑦)."""

    def __init__(self, operators: list[GPO]):
        self.operators = list(operators)

    def names(self) -> list[str]:
        return [op.name for op in self.operators]

    def append(self, op: GPO):
        self.operators.append(op)
        return self

    def insert_after(self, name: str, op: GPO):
        for i, existing in enumerate(self.operators):
            if existing.name == name:
                self.operators.insert(i + 1, op)
                return self
        raise KeyError(f"no GPO named {name!r}")

    def replace(self, name: str, op: GPO):
        for i, existing in enumerate(self.operators):
            if existing.name == name:
                self.operators[i] = op
                return self
        raise KeyError(f"no GPO named {name!r}")


class Pipeline(OperatorList):
    """The target-phase pipeline: runs per (target, config) on a shared,
    already-validated corpus."""

    def run(self, config: GenConfig, *, corpus=None,
            strict: bool = True) -> GenerationResult:
        if corpus is None:
            from .corpus import load_corpus

            corpus = load_corpus(config.upd_paths)
        ctx = GenerationResult(config=config, corpus=corpus,
                               warnings=list(corpus.warnings))
        ctx.meta["fingerprint"] = corpus.fingerprint
        for op in self.operators:
            ctx = op.run(ctx)
            if ctx.errors and strict:
                raise GenerationError(ctx.errors, ctx.warnings)
        return ctx


def core_pipeline(config: GenConfig) -> Pipeline:
    """The target-phase core (paper ①) + configured extension GPOs."""
    from .benchgen import BenchSelectGPO
    from .buildgen import BuildGenGPO
    from .docgen import DocGenGPO
    from .generate import GenerateGPO
    from .select import SelectGPO
    from .testgen import TestGenGPO

    pipe = Pipeline([SelectGPO(), GenerateGPO()])
    # extension port ⑦
    if config.use_bench_selection:
        pipe.insert_after("select", BenchSelectGPO())
    if config.emit_tests:
        pipe.append(TestGenGPO())
    if config.emit_build:
        pipe.append(BuildGenGPO())
    if config.emit_docs:
        pipe.append(DocGenGPO())
    return pipe
