"""Mamba2 block (zamba2 backbone) on TSL seq primitives.

Block: in_proj -> [z | x | B | C | dt] -> causal_conv1d(x) -> SSD -> gated
rmsnorm -> out_proj. Scalar-per-head decay a = exp(-exp(A_log)·softplus(dt)),
input scaled by dt (the SSD discretization).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.tsl_api import ops as tsl

from .common import dense_init, split_keys


def dims(cfg):
    d_in = cfg.d_inner_mult * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state, cfg.ssm_head_dim


def init_mamba2(key, cfg, dtype):
    d = cfg.d_model
    d_in, nh, n, p_dim = dims(cfg)
    ks = split_keys(key, 4)
    proj_out = 2 * d_in + 2 * n + nh
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, d_in), dtype, scale=0.5),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "gate_norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], (d_in, d), dtype),
    }


def _split_proj(zxbcdt, cfg):
    d_in, nh, n, _ = dims(cfg)
    z = zxbcdt[..., :d_in]
    x = zxbcdt[..., d_in:2 * d_in]
    b = zxbcdt[..., 2 * d_in:2 * d_in + n]
    c = zxbcdt[..., 2 * d_in + n:2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n:]
    return z, x, b, c, dt


def _discretize(p, dt_raw, x, cfg):
    """-> (a (B,T,H) decay, x_scaled (B,T,H,P))."""
    _, nh, _, p_dim = dims(cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)
    xh = x.reshape(*x.shape[:-1], nh, p_dim)
    x_scaled = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    return a.astype(x.dtype), x_scaled, xh


def mamba2_forward(p, x_seq, cfg, *, h0=None, conv_prev=None, n_real=None):
    """x_seq: (B,T,D) -> (y (B,T,D), (h_final, conv_tail)).

    ``n_real`` (scalar or (B,) per-sequence, may be traced): positions
    >= n_real are padding —
    their SSD update is forced to the identity (decay 1, input 0) so
    ``h_final`` is exactly the state after the last REAL token, and the conv
    tail ends at the last real row. Their y rows are garbage the caller
    discards. ``conv_prev`` ((B, KW-1, d_in)) continues a prior chunk's conv
    window; zeros == fresh start (causal_conv1d zero-pads identically)."""
    bsz, t, d = x_seq.shape
    d_in, nh, n, p_dim = dims(cfg)
    kw = cfg.conv_width
    zxbcdt = tsl.matmul(x_seq, p["in_proj"])
    z, xr, b, c, dt_raw = _split_proj(zxbcdt, cfg)
    if conv_prev is None and kw > 1:
        conv_prev = jnp.zeros((bsz, kw - 1, xr.shape[-1]), xr.dtype)
    if kw > 1:
        xr_in = jnp.concatenate([conv_prev.astype(xr.dtype), xr], axis=1)
        xc = tsl.causal_conv1d(xr_in, p["conv_w"])[:, kw - 1:]
    else:
        xr_in = xr
        xc = tsl.causal_conv1d(xr, p["conv_w"])
    xc = tsl.silu(xc)
    a, x_scaled, xh = _discretize(p, dt_raw, xc, cfg)
    if n_real is not None:
        nr = jnp.asarray(n_real)
        nr = nr[:, None] if nr.ndim else nr     # (B,) per-sequence or scalar
        valid = jnp.arange(t)[None, :] < nr                  # (1|B, T)
        a = jnp.where(valid[:, :, None], a, jnp.ones_like(a))
        x_scaled = jnp.where(valid[:, :, None, None], x_scaled,
                             jnp.zeros_like(x_scaled))
    y, h_final = tsl.ssd_scan(x_scaled, a, b, c, h0=h0)
    y = y + p["D_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(bsz, t, d_in)
    y = tsl.rmsnorm(y * tsl.silu(z), p["gate_norm_w"], eps=cfg.norm_eps)
    if kw > 1:
        # window of KW-1 rows ending at the last real row: xr_in row
        # (kw-1) + n_real - 1 — a dynamic slice so n_real may be traced
        # (and it degrades gracefully to leading zeros when n_real < KW-1)
        end = t if n_real is None else jnp.asarray(n_real)
        if getattr(end, "ndim", 0):             # (B,) per-sequence ends
            idx = end[:, None] + jnp.arange(kw - 1)[None, :]    # (B, KW-1)
            conv_tail = jnp.take_along_axis(xr_in, idx[:, :, None], axis=1)
        else:
            conv_tail = jax.lax.dynamic_slice_in_dim(xr_in, end, kw - 1, axis=1)
    else:
        conv_tail = None
    return tsl.matmul(y, p["out_proj"]), (h_final, conv_tail)


def mamba2_decode(p, x_t, cfg, h, conv_cache):
    """One step. x_t (B,1,D); h (B,H,P,N) f32; conv_cache (B,KW-1,d_in)."""
    bsz, _, d = x_t.shape
    d_in, nh, n, p_dim = dims(cfg)
    zxbcdt = tsl.matmul(x_t, p["in_proj"])
    z, xr, b, c, dt_raw = _split_proj(zxbcdt, cfg)
    window = jnp.concatenate([conv_cache, xr], axis=1)      # (B,KW,d_in)
    conv_cache = window[:, 1:]
    xc = jnp.sum(window.astype(jnp.float32)
                 * p["conv_w"].astype(jnp.float32)[None], axis=1, keepdims=True)
    xc = tsl.silu(xc.astype(x_t.dtype))
    a, x_scaled, xh = _discretize(p, dt_raw, xc, cfg)
    yt, h = tsl.ssd_decode(x_scaled[:, 0], a[:, 0], b[:, 0], c[:, 0], h)
    yt = yt + p["D_skip"][None, :, None].astype(yt.dtype) * xh[:, 0]
    yt = yt.reshape(bsz, 1, d_in)
    yt = tsl.rmsnorm(yt * tsl.silu(z), p["gate_norm_w"], eps=cfg.norm_eps)
    return tsl.matmul(yt, p["out_proj"]), h, conv_cache
