"""Pallas TPU kernel: fused RMSNorm over row blocks.

Tiling: x is viewed as (rows, d); the grid walks row blocks of
``block_rows`` × d. One VMEM tile holds the row block plus the (1, d) weight
(broadcast to every grid step via a constant index map). Statistics are
computed in f32 on-tile, so bf16 inputs never round-trip through HBM in f32.

VMEM budget (v5e SRU, 128 MiB): block_rows=256, d=8192, bf16 in+out tiles +
f32 intermediates ≈ 256·8192·(2+2+4+4) B ≈ 25 MiB — comfortably inside.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from ..common import cdiv


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_2d(x, weight, *, eps: float = 1e-6, block_rows: int = 256,
               interpret: bool = False):
    """x: (rows, d), weight: (d,) -> (rows, d). rows % block_rows == 0 assumed
    (ops.py pads)."""
    rows, d = x.shape
    grid = (cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name="tsl_rmsnorm",
    )(x, weight.reshape(1, d))
