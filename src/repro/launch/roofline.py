"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), from the task brief:
    compute   = HLO_FLOPs / (chips × peak_FLOP/s)
    memory    = HLO_bytes / (chips × HBM_bw)
    collective= Σ effective collective bytes (per-device) / link_bw

HLO shapes in an SPMD module are PER-DEVICE, so cost_analysis flops/bytes are
per-device too — the "chips ×" division is already done by GSPMD; we therefore
use the per-device numbers directly against per-chip peaks.

Collective bytes come from parsing the compiled HLO text (cost_analysis does
not expose them): every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute result shape is summed with ring-model effective factors
(all-reduce 2x: reduce-scatter + all-gather phases).
"""

from __future__ import annotations

import re
from dataclasses import dataclass

# v5e constants (task brief)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")

# ring-model effective traffic multiplier on the RESULT shape
_FACTORS = {
    "all-reduce": 2.0,        # reduce-scatter + all-gather phases
    "all-gather": 1.0,
    "reduce-scatter": 1.0,    # (operand is result × shards; result-based ≈ lower bound)
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    effective_bytes: float

    def as_dict(self):
        return {"counts": self.counts, "bytes_by_kind": self.bytes_by_kind,
                "effective_bytes": self.effective_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    bytes_by_kind: dict[str, int] = {}
    effective = 0.0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        for kind in _COLLECTIVE_KINDS:
            # match the op name: "<result shape> all-reduce(" or "-start("
            if re.search(rf"\s{kind}(-start)?\(", rhs):
                result_part = rhs.split(f" {kind}")[0]
                b = _shape_bytes(result_part)
                counts[kind] = counts.get(kind, 0) + 1
                bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + b
                effective += _FACTORS[kind] * b
                break
    return CollectiveStats(counts, bytes_by_kind, effective)


# XLA:CPU legalizes bf16 compute to f32, inflating "bytes accessed" ~2x vs
# the bf16 TPU execution the mesh targets. We report BOTH the raw HLO term
# (the brief's formula, comparable across §Perf iterations) and a bf16-
# adjusted term (x0.5, used for dominance classification so hillclimbs attack
# the right wall). Methodology note in EXPERIMENTS.md §Roofline.
BF16_ADJ = 0.5


def roofline_terms(cost: dict, coll: CollectiveStats) -> dict:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_memory_adj = byts * BF16_ADJ / HBM_BW
    t_collective = coll.effective_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory_adj,
             "collective_s": t_collective}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    total = max(bound, 1e-30)
    return {
        "compute_s": t_compute,
        "memory_s_raw": t_memory,
        "memory_s": t_memory_adj,
        "collective_s": t_collective,
        "dominant": dom.replace("_s", ""),
        "roofline_bound_s": bound,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": byts,
        "overlap_fraction": (sum(terms.values()) - bound) / total,
    }


def model_flops(cfg, cell, chips: int) -> float:
    """Analytic useful-work FLOPs PER DEVICE for the cell (6ND train / 2ND
    inference + attention term), for the MODEL_FLOPS/HLO_FLOPs ratio."""
    n_params = cfg.param_count(active_only=(cfg.family == "moe"))
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        tokens = B * S
        flops = 6.0 * n_params * tokens
        if cfg.family not in ("ssm",):
            l_attn = cfg.n_layers if cfg.family != "hybrid" else \
                cfg.n_layers // max(cfg.attn_every, 1)
            flops += 6.0 * 2.0 * l_attn * B * S * S * cfg.n_heads * cfg.hd / 2
    elif cell.kind == "prefill":
        tokens = B * S
        flops = 2.0 * n_params * tokens
        if cfg.family not in ("ssm",):
            l_attn = cfg.n_layers if cfg.family != "hybrid" else \
                cfg.n_layers // max(cfg.attn_every, 1)
            flops += 2.0 * 2.0 * l_attn * B * S * S * cfg.n_heads * cfg.hd / 2
    else:  # decode: one token, full KV/state read
        flops = 2.0 * n_params * B
        if cfg.family not in ("ssm", "hybrid"):
            flops += 4.0 * cfg.n_layers * B * S * cfg.n_heads * cfg.hd
    return flops / chips
