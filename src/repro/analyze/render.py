"""Stage-1 rendering of UPD definition bodies for analysis (TSL03x/TSL04x).

Definition bodies are Jinja2 stage-1 templates (paper §3.2 ③); the tiling and
safety analyzers need the *rendered* Python the generator would actually emit.
Each definition is rendered once against its own target SRU and a
representative ctype, with the implementation wrapped as a function body so
``return`` statements parse::

    <helpers module-level code>
    def _impl(<params>):
        <implementation body>

Render or parse failures become TSL040 upstream (``error`` on the
:class:`RenderedBody`) instead of crashing the analysis pass.
"""

from __future__ import annotations

import ast
import textwrap
from dataclasses import dataclass

from repro.core import engine


@dataclass(frozen=True)
class RenderedBody:
    primitive: str
    def_index: int
    target: str
    ctype: str
    sublanes: int
    lanes: int
    source: str                 # helpers + wrapped implementation (a module)
    tree: ast.Module | None
    error: str = ""


def _pick_ctype(impl, target) -> str | None:
    """A representative ctype the engine can render dtype helpers for."""
    for ct in impl.ctypes:
        try:
            engine.dtype_info(ct)
        except KeyError:
            continue
        return ct
    return None


def render_bodies(corpus) -> list[RenderedBody]:
    out: list[RenderedBody] = []
    for name in sorted(corpus.primitives):
        prim = corpus.primitives[name]
        for i, d in enumerate(prim.definitions):
            tgt = corpus.targets.get(d.target_extension)
            if tgt is None:
                continue    # unknown target: already a validation error
            ct = _pick_ctype(d, tgt)
            if ct is None:
                continue    # no renderable dtype — nothing to analyze
            try:
                helpers = engine.render_stage1(
                    d.helpers, sru=tgt.as_render_dict(), ctype=ct,
                    primitive=name, params=prim.arg_names()) if d.helpers else ""
                body = engine.render_stage1(
                    d.implementation, sru=tgt.as_render_dict(), ctype=ct,
                    primitive=name, params=prim.arg_names())
            except Exception as e:  # jinja2 errors are library-specific
                out.append(RenderedBody(name, i, tgt.name, ct, tgt.sublanes,
                                        tgt.lanes, "", None,
                                        error=f"stage-1 render failed: {e}"))
                continue
            sig = ", ".join(prim.arg_names()) or ""
            src = (f"{helpers}\n\ndef _impl({sig}):\n"
                   + textwrap.indent(body or "pass", "    ") + "\n")
            try:
                tree = ast.parse(src)
            except SyntaxError as e:
                out.append(RenderedBody(name, i, tgt.name, ct, tgt.sublanes,
                                        tgt.lanes, src, None,
                                        error=f"rendered body does not parse: "
                                              f"{e.msg} (line {e.lineno})"))
                continue
            out.append(RenderedBody(name, i, tgt.name, ct, tgt.sublanes,
                                    tgt.lanes, src, tree))
    return out
