"""Pallas TPU kernel: blockwise flash attention with GQA head folding.

TPU adaptation of the (GPU-origin) FlashAttention online-softmax algorithm
(DESIGN.md §2): instead of warp-level shared-memory staging, blocks of
Q (bq × D) and K/V (bk × D) are staged HBM→VMEM by the Pallas pipeline; the
two matmuls per step are MXU-shaped (bq,D)x(D,bk) and (bq,bk)x(bk,D) with
f32 VREG accumulators held in VMEM scratch across the sequential k-grid.

Grid: (B, H, Sq/bq, Sk/bk) — the last dimension is "arbitrary" (sequential)
so the running (m, l, acc) scratch carries across k blocks; the first three
are "parallel". GQA is folded via the K/V index maps (h -> h // group), so
KV blocks are fetched once per KV head group without materializing the
H-times-replicated cache in HBM — that replication is exactly the waste the
GPU implementations avoid with shared memory, adapted here to VMEM reuse.

VMEM per step (bq=bk=512, D=128, bf16): q 128K, k/v 256K, acc f32 256K,
p f32 1M — ≈ 2 MiB, far under the v5e budget; larger bq trades grid steps
for VMEM (hillclimb lever recorded in EXPERIMENTS.md §Perf).

Causal masking uses global row/col iota comparison; fully-masked (qi, ki)
tiles still execute (static grid) — skipping them is the classic 2x win,
implemented as an early-exit `when` on the block predicate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


NEG_INF = -1e30
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, kv_len: int, q_offset: int,
                  bq: int, bk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # global positions of this tile (ends-aligned causal: logical q row r
    # attends to keys <= r + q_offset, supporting prefill continuation;
    # q_offset = kv_len - logical_sq, computed on the UNPADDED q length)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level early exit: skip fully-masked causal tiles
    block_needed = jnp.logical_or(
        jnp.logical_not(causal),
        (ki * bk) <= (qi * bq + bq - 1 + q_offset),
    )

    @pl.when(block_needed)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]                         # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_new = corr * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[:, :1]
        o = acc_scr[...] / jnp.maximum(l, 1e-30)
        o = jnp.where(l > 0.0, o, 0.0)
        o_ref[0, 0] = o.astype(o_ref.dtype)


def flash_attention_4d(q, k, v, *, causal: bool = True, scale: float | None = None,
                       kv_len: int | None = None, q_offset: int | None = None,
                       block_q: int = 512, block_k: int = 512,
                       interpret: bool = False):
    """q: (B,H,Sq,D); k,v: (B,KH,Sk,D). Shapes pre-padded to block multiples.

    ``q_offset``: causal alignment of logical q row 0 (defaults kv_len - sq)."""
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    assert h % kh == 0, (h, kh)
    group = h // kh
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kv_len = kv_len if kv_len is not None else sk
    q_offset = q_offset if q_offset is not None else kv_len - sq

    grid = (b, h, sq // bq, sk // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, kv_len=kv_len,
        q_offset=q_offset, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),   # running max (lane-replicated)
            pltpu.VMEM((bq, _LANES), jnp.float32),   # running denominator
            pltpu.VMEM((bq, d), jnp.float32),        # output accumulator
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="tsl_flash_attention",
    )(q, k, v)
