"""Hardware probe (paper Fig 7a: ``cpuinfo.get_cpu_info()['flags']`` feeding
``--targets``). Here: query the live JAX backend and map it to an SRU name +
flag set. The generator can also be "tricked into assuming specific hardware"
(paper §4.1) by passing explicit flags — that is exactly how we generate the
TPU library on this CPU-only container."""

from __future__ import annotations

import jax

_BACKEND_TO_TARGET = {
    "cpu": "cpu_xla",
    "tpu": "tpu_v5e",
    "gpu": "cpu_xla",  # conservative fallback: portable XLA path
}


def live_target() -> str:
    return _BACKEND_TO_TARGET.get(jax.default_backend(), "cpu_xla")


def live_flags() -> tuple[str, ...]:
    backend = jax.default_backend()
    flags = ["xla", backend]
    if backend == "tpu":
        flags += ["mxu", "vmem", "bf16_matmul"]
        kind = jax.devices()[0].device_kind.lower()
        if "v5" in kind:
            flags.append("tpu_v5")
        if "v4" in kind:
            flags.append("tpu_v4")
    if backend == "cpu":
        flags += ["f64", "interpret_ok"]
    return tuple(sorted(set(flags)))
