"""Primitive-level microbenchmarks: generated TSL call vs direct jnp for the
hot primitives (zero-abstraction-overhead check at the primitive granularity
— the paper's 'compile-time deduction and code generation with zero overhead
for the runtime').
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import load_library
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rmsnorm import ref as rms_ref

from .common import emit, time_fn


def run() -> list[str]:
    lib = load_library("cpu_xla")
    rng = np.random.default_rng(0)
    out = []

    x = jnp.asarray(rng.normal(size=(4096, 1024)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(1024,)), jnp.float32)
    t_tsl = time_fn(jax.jit(lambda a: lib.ops.rmsnorm(a, w)), x)
    t_raw = time_fn(jax.jit(lambda a: rms_ref.rmsnorm(a, w)), x)
    emit("prim_rmsnorm_tsl", t_tsl, f"overhead={(t_tsl-t_raw)/t_raw*100:+.1f}%")
    emit("prim_rmsnorm_direct", t_raw, "")
    out.append(f"rmsnorm overhead {(t_tsl-t_raw)/t_raw*100:+.1f}%")

    q = jnp.asarray(rng.normal(size=(2, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 512, 64)), jnp.float32)
    t_tsl = time_fn(jax.jit(lambda a: lib.ops.flash_attention(a, k, v)), q, n_iter=10)
    t_raw = time_fn(jax.jit(lambda a: fa_ref.attention(a, k, v)), q, n_iter=10)
    emit("prim_attention_tsl", t_tsl, f"overhead={(t_tsl-t_raw)/t_raw*100:+.1f}%")
    emit("prim_attention_direct", t_raw, "")
    out.append(f"attention overhead {(t_tsl-t_raw)/t_raw*100:+.1f}%")

    a = jnp.asarray(rng.normal(size=(1024, 1024)), jnp.bfloat16)
    b = jnp.asarray(rng.normal(size=(1024, 1024)), jnp.bfloat16)
    t_tsl = time_fn(jax.jit(lambda x_: lib.ops.matmul(x_, b)), a)
    t_raw = time_fn(jax.jit(lambda x_: jnp.matmul(x_, b)), a)
    emit("prim_matmul_tsl", t_tsl, f"overhead={(t_tsl-t_raw)/t_raw*100:+.1f}%")
    emit("prim_matmul_direct", t_raw, "")
    out.append(f"matmul overhead {(t_tsl-t_raw)/t_raw*100:+.1f}%")
    return out


if __name__ == "__main__":
    run()
