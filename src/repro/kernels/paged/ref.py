"""Paged cache gather/scatter: the reference bodies behind the
``cache_page_read`` / ``cache_page_write`` UPD primitives.

The pool is a FLAT token-row store ``(capacity_rows, *row_shape)``: one row
per cache token, trailing dims free (a KV row, an (L, KH, hd) stack, an int8
row + its scale row — the primitives are layout-agnostic). A page is
``page_size`` CONSECUTIVE rows, and the page table passed to the primitives
holds each page's STARTING ROW offset, so the same pool array serves any
page-size candidate — the vector-length-agnostic discipline (ARM SVE)
applied to cache geometry: page size is a property of the *definition*, not
of the call site.

Two schedules, mirroring the flash-attention block_k candidates:

* ``page_read``/``page_write`` with small pages — one flat index gather /
  scatter (``jnp.take`` / ``.at[].set``): many small slices, fine-grained
  residency, more index traffic.
* the ``*_blocked`` variants — one ``dynamic_slice`` per page: contiguous
  page-sized block copies, the Mosaic/Triton-friendly schedule for large
  pages (a 256-row page of 128-wide rows is a whole (sublane, lane)-aligned
  tile stream).

Bench selection (``python -m repro.core bench``) times the candidates per
hardware key; the winning definition's page size is what the serving layer
builds its pools with (``repro.serve.paging.selected_page_size`` probes it).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def page_read(pool, table, *, page: int):
    """Gather ``page`` consecutive rows per table entry.

    pool: (cap_rows, *row); table: (N,) int32 page start-row offsets.
    Returns (N * page, *row), pages concatenated in table order."""
    rows = (table[:, None] + jnp.arange(page, dtype=table.dtype)).reshape(-1)
    return jnp.take(pool, rows, axis=0)


def page_read_blocked(pool, table, *, page: int):
    """Same semantics as :func:`page_read`, one contiguous dynamic_slice per
    page — the large-page schedule."""

    def one(start):
        return jax.lax.dynamic_slice_in_dim(pool, start, page, axis=0)

    out = jax.vmap(one)(table)                      # (N, page, *row)
    return out.reshape((-1,) + pool.shape[1:])


def page_write(pool, rows, table, *, page: int):
    """Scatter ``page`` consecutive rows per table entry into the pool.

    rows: (N * page, *row) content in table order; returns the updated pool."""
    idx = (table[:, None] + jnp.arange(page, dtype=table.dtype)).reshape(-1)
    return pool.at[idx].set(rows.astype(pool.dtype))


def paged_attention_ref(q, k_pool, v_pool, tables, kv_len, *, k_scale=None,
                        v_scale=None, scale=None, pages_per_step: int = 1):
    """Gather-free paged attention: decode/verify straight off the page pool.

    q: (B, H, SQ, D) — SQ = 1 for decode, the verify span width otherwise.
    k_pool/v_pool: (KH, n_pages, page, D) — the WHOLE pool, every resident
    request's pages interleaved. tables: (B, P) int32 PAGE IDS (indices into
    the pool's page axis — not the start-row offsets ``page_read`` takes:
    the block table never leaves page-id space here, which is the point).
    kv_len: (B,) int32 rows written per sequence; rows of page p beyond it
    are masked, and table entries past the covered range must still be
    *valid* page ids (a scratch page) — they are fetched, then masked.

    The span is ends-aligned at kv_len (row r of SQ sits at absolute
    position kv_len - SQ + r), matching ``attention_verify``. int8 pools
    pass per-row ``k_scale``/``v_scale`` pools of shape (KH, n_pages, page,
    1); dequantization happens per touched page inside the scan — never at
    a park/activate boundary. kv_len == 0 rows return exactly 0.

    ``pages_per_step`` is the scan's key-block knob (the ref-side analogue
    of the Pallas ``block_k`` candidates): each step fetches that many table
    entries and runs one page-group-wide online-softmax update. The table
    is padded to a multiple with its own first entry — padded positions sit
    past every query row and mask out.
    """
    b, h, sq, d = q.shape
    kh, _, page, _ = k_pool.shape
    group = h // kh
    n_p = tables.shape[1]
    sc = scale if scale is not None else 1.0 / (d ** 0.5)
    r = group * sq
    # heads are KV-head-major (h = kh * group + g), as in the flash kernels
    qf = q.astype(jnp.float32).reshape(b, kh, r, d)
    kvl = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    tables = jnp.asarray(tables, jnp.int32)
    # absolute query position of span row (row % sq), per sequence
    qi = kvl[:, None] - sq + (jnp.arange(r, dtype=jnp.int32) % sq)[None, :]

    g = max(int(pages_per_step), 1)
    n_steps = -(-n_p // g)
    if n_steps * g != n_p:
        # pad with each sequence's own first entry: padded logical positions
        # are >= n_p * page > every qi, so they mask out below
        pad = jnp.broadcast_to(tables[:, :1], (b, n_steps * g - n_p))
        tables = jnp.concatenate([tables, pad], axis=1)
    grouped = tables.reshape(b, n_steps, g)                  # scan xs, axis 1
    width = g * page

    def step(carry, xs):
        m, l, acc = carry
        p, pid = xs                                          # (), (B, G)
        k = jnp.take(k_pool, pid, axis=1)                # (KH, B, G, page, D)
        v = jnp.take(v_pool, pid, axis=1)
        if k_scale is not None:
            k = k.astype(jnp.float32) * jnp.take(k_scale, pid, axis=1)
            v = v.astype(jnp.float32) * jnp.take(v_scale, pid, axis=1)
        k = jnp.moveaxis(k, 1, 0).astype(jnp.float32)
        v = jnp.moveaxis(v, 1, 0).astype(jnp.float32)
        k = k.reshape(b, kh, width, d)                   # (B, KH, G*page, D)
        v = v.reshape(b, kh, width, d)
        s = jnp.einsum("bkrd,bkcd->bkrc", qf, k) * sc
        kpos = p * width + jnp.arange(width, dtype=jnp.int32)  # logical pos
        valid = kpos[None, None, :] <= qi[:, :, None]        # (B, R, width)
        s = jnp.where(valid[:, None], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        pr = jnp.where(valid[:, None], jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + pr.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bkrc,bkcd->bkrd", pr, v)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kh, r), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, r), jnp.float32)
    a0 = jnp.zeros((b, kh, r, d), jnp.float32)
    (_, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(n_steps, dtype=jnp.int32), jnp.moveaxis(grouped, 1, 0)))
    out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l, 1e-30)[..., None],
                    0.0)
    return out.reshape(b, h, sq, d).astype(q.dtype)


def page_write_blocked(pool, rows, table, *, page: int):
    """Same semantics as :func:`page_write`, one contiguous
    dynamic_update_slice per page — the large-page schedule."""
    blocks = rows.astype(pool.dtype).reshape((-1, page) + pool.shape[1:])

    def one(p, sb):
        start, blk = sb
        return jax.lax.dynamic_update_slice_in_dim(p, blk, start, axis=0), 0

    pool, _ = jax.lax.scan(one, pool, (table, blocks))
    return pool
