"""Rotary position embedding tables (half-split layout, matches TSL rope_apply)."""

from __future__ import annotations

import jax.numpy as jnp


def rope_tables(positions, head_dim: int, theta: float = 1e4):
    """positions: int array (...,) -> (cos, sin) of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)
