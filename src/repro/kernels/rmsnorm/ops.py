"""Public wrapper for the RMSNorm kernel: shape-polymorphic, padded tiling."""

from __future__ import annotations

from functools import partial

import jax

from ..common import pad_to, round_up, sublane_multiple
from . import kernel, ref


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, weight, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """RMSNorm over the last axis of an arbitrary-rank input."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    # tile alignment: rows to block multiple, block to sublane multiple
    br = max(sublane_multiple(x.dtype), min(block_rows, round_up(rows, sublane_multiple(x.dtype))))
    x2, n = pad_to(x2, 0, br)
    out = kernel.rmsnorm_2d(x2, weight, eps=eps, block_rows=br,
                            interpret=interpret)
    return out[:n].reshape(orig_shape)


__all__ = ["rmsnorm", "ref"]
