"""Trace-time flags (read at trace time; set by dryrun variants).

SCAN_UNROLL: when True, layer scans unroll — used by the dry-run's
depth-1/depth-2 lowerings so XLA's cost analysis (which counts a while-loop
body ONCE, regardless of trip count) sees every layer. Roofline terms are
then extrapolated: cost(L) = cost(1) + (L-1)·[cost(2) - cost(1)].
"""

SCAN_UNROLL: bool = False

# Sequence-parallel TP (Korthikanti et al.): residual stream sharded over
# sequence on the model axis between blocks; GSPMD then lowers the per-block
# boundary to reduce-scatter + all-gather instead of full all-reduces and
# norms/residual math runs 1/TP-sharded. Enabled per dry-run via --sp.
SEQUENCE_PARALLEL: bool = False

# Expert parallelism (expert dim sharded on the data axes where divisible).
# Measured WORSE than capacity-dim sharding on the (16,16) dry-run metric
# (arctic train 126.7 -> 131.1 s, §Perf) — default off, kept as a lever.
EXPERT_PARALLEL: bool = False


def scan_unroll():
    return True if SCAN_UNROLL else 1


def residual_axes():
    return ("batch", "seqtp", None) if SEQUENCE_PARALLEL else ("batch", None, None)
