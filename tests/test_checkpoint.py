"""Checkpointing: atomic commit, restore-latest, corruption detection,
async writer, and restart-continuation through the training launcher."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import Checkpointer


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 5, (3,)), jnp.int32),
                   "c": jnp.asarray(rng.normal(size=(2, 2)), jnp.bfloat16)},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(3, t, extra={"data": {"step": 3}}, async_=False)
    restored, extra = ck.restore(3, jax.eval_shape(lambda: t))
    assert extra == {"data": {"step": 3}}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_restore_latest_picks_highest_committed(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree(1), async_=False)
    ck.save(5, _tree(5), async_=False)
    # a torn write (no manifest) must be ignored
    (tmp_path / "step_9").mkdir()
    step, tree, _ = ck.restore_latest(jax.eval_shape(lambda: _tree()))
    assert step == 5


def test_corruption_detected(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree(), async_=False)
    leaf = next((tmp_path / "step_1").glob("leaf_0.npy"))
    arr = np.load(leaf)
    arr_view = arr.view(np.uint8).copy()
    arr_view[0] ^= 0xFF
    np.save(leaf, arr_view.view(arr.dtype).reshape(arr.shape))
    with pytest.raises(IOError, match="checksum"):
        ck.restore(1, jax.eval_shape(lambda: _tree()))


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(2, _tree(), async_=True)
    ck.wait()
    assert ck.completed_steps() == [2]


def test_gc_keeps_last_k(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s), async_=False)
    assert ck.completed_steps() == [3, 4]


def test_elastic_restore_with_shardings(tmp_path, host_mesh):
    """Checkpoint saved unsharded restores under explicit NamedShardings —
    the (16,16)->(8,16) elastic path exercised at CPU scale."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(1, t, async_=False)
    sh = jax.tree.map(lambda _: NamedSharding(host_mesh, P()), t)
    restored, _ = ck.restore(1, jax.eval_shape(lambda: t), shardings=sh)
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding is not None


def test_train_restart_continues(tmp_path):
    """Kill-and-restart semantics through the real launcher: 6 steps, 'crash',
    restart resumes from the checkpoint and reaches 12 total."""
    from repro.launch.train import main

    args = ["--arch", "qwen1.5-0.5b", "--reduced", "--batch", "2", "--seq", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "3", "--log-every", "100"]
    r1 = main(args + ["--steps", "6"])
    assert (tmp_path / "step_6").exists()
    r2 = main(args + ["--steps", "12"])   # restarts from 6
    assert r2["final_loss"] is not None
    steps = json.loads((tmp_path / "step_12" / "manifest.json").read_text())
    assert steps["extra"]["data"]["step"] == 12
