"""Sharding-rule unit tests (no 512-device env needed: rules are pure)."""

import jax
import jax.numpy as jnp
import numpy as np


def _sds(shape, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-in — NEVER allocate multi-GiB test params."""
    return jax.ShapeDtypeStruct(shape, dtype)
from jax.sharding import PartitionSpec as P

from repro.dist import sharding


class _FakeMesh:
    """Shape-only stand-in so rules can be tested against the production mesh
    geometry without 512 devices."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)
        self.axis_sizes = shape


def test_param_rules_production_geometry():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    params = {
        "embed": _sds((64000, 7168)),
        "blocks": {
            "attn": {"wq": _sds((60, 7168, 7168)),
                     "wo": _sds((60, 7168, 7168))},
            "moe": {"w_gate": _sds((35, 128, 7168, 4864))},
            "attn_norm": {"w": _sds((60, 7168))},
        },
        "head": _sds((7168, 64000)),
    }
    specs = sharding.param_specs(mesh, params)
    assert specs["embed"] == P("model", "data")
    assert specs["blocks"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["blocks"]["attn"]["wo"] == P(None, "model", "data")
    assert specs["blocks"]["moe"]["w_gate"] == P(None, None, "data", "model")
    assert specs["blocks"]["attn_norm"]["w"] == P()          # 1D replicated
    assert specs["head"] == P("data", "model")


def test_param_rules_multipod_folds_dp():
    mesh = _FakeMesh((2, 16, 16), ("pod", "data", "model"))
    params = {"head": _sds((12288, 32768))}
    specs = sharding.param_specs(mesh, params)
    assert specs["head"] == P(("pod", "data"), "model")


def test_tiny_dims_not_oversharded():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    params = {"wq": _sds((8, 4))}   # smaller than mesh
    specs = sharding.param_specs(mesh, params)
    assert specs["wq"] == P(None, None)


def test_state_specs_kv_cache_sequence_parallel():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    state = {"k": _sds((60, 128, 8, 32768, 128)),
             "v": _sds((60, 128, 8, 32768, 128))}
    specs = sharding.state_specs(mesh, state)
    assert specs["k"] == P(None, "data", None, "model", None)


def test_state_specs_batch1_keeps_seq_sharding():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    state = {"k": _sds((81, 1, 32, 524288, 112))}
    specs = sharding.state_specs(mesh, state)
    # batch of 1 cannot shard on data; sequence still shards on model
    assert specs["k"] == P(None, None, None, "model", None)


def test_state_specs_huge_batch_does_not_steal_model_axis():
    """Decode batch larger than max_len: batch stays on data, seq on model."""
    mesh = _FakeMesh((16, 16), ("data", "model"))
    state = {"k": _sds((60, 4096, 8, 1024, 128))}
    specs = sharding.state_specs(mesh, state)
    assert specs["k"] == P(None, "data", None, "model", None)


def test_batch_spec_divisibility():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    assert sharding.batch_spec(mesh, 256) == P("data", None)
    assert sharding.batch_spec(mesh, 1) == P(None)


def test_logical_constraint_noop_without_mesh():
    x = jnp.zeros((4, 8))
    y = sharding.logical_constraint(x, "batch", None)
    assert y.shape == x.shape
