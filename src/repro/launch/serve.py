"""Serving launcher: thin CLI over repro.serve.ServeEngine (per-step
continuous batching with chunked prefill — prompts are padded to
UPD-declared length buckets, prefill advances one fixed-size chunk per
unified step alongside decode, admission is cost-model gated, and sampling
is configurable).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
        --batch 4 --prompt-len 32 --gen-len 32 --requests 8 \
        --temperature 0.8 --top-k 40 --sla-ms 500 --prefill-chunk 8
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.serve import BucketPolicy, Request, SamplingConfig, ServeEngine


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="slot-table size (decode batch)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy argmax")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation (0 = full distribution)")
    ap.add_argument("--sla-ms", type=float, default=None,
                    help="per-request end-to-end deadline; feeds both "
                         "cost-model admission and the hit-rate report")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prefill tokens per unified step (default: the "
                         "UPD-declared serve chunk; declared buckets round "
                         "up to whole chunks)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # serving different archs in one process: drop jit caches so recycled
    # function ids from a previous model cannot alias stale executables
    jax.clear_caches()

    # budget the slot table for the decode prefix (vlm vision rows) AND the
    # length bucket the prompt pads to, or admission would refuse every
    # request by construction; a prompt beyond the largest declared bucket
    # extends the bucket set (rounded to whole chunks) instead of refusing
    policy = BucketPolicy.from_upd(chunk=args.prefill_chunk)
    bucket = policy.assign(args.prompt_len)
    buckets = None
    if bucket is None:
        bucket = BucketPolicy.round_up(args.prompt_len, policy.chunk)
        buckets = policy.buckets + (bucket,)
    engine = ServeEngine(
        cfg, batch=args.batch,
        max_len=cfg.decode_prefix + bucket + args.gen_len,
        sampling=SamplingConfig(temperature=args.temperature,
                                top_k=args.top_k),
        seed=args.seed,
        prefill_chunk=args.prefill_chunk, buckets=buckets,
        enc_len=args.prompt_len if cfg.family == "audio" else None)

    rng = np.random.default_rng(args.seed)
    sla_s = args.sla_ms / 1e3 if args.sla_ms is not None else None
    requests = [
        Request(rid=f"req{i}",
                tokens=rng.integers(0, cfg.vocab, args.prompt_len
                                    ).astype(np.int32),
                gen_len=args.gen_len, sla_s=sla_s)
        for i in range(args.requests)
    ]

    report = engine.run(requests)
    first = report["outputs"].get("req0", [])
    result = {
        "arch": cfg.name,
        "requests": report["requests"],
        "decode_tokens_per_s": report["decode_tokens_per_s"],
        "ttft_s_mean": report["ttft_s_mean"],
        "sla_hit_rate": report["sla_hit_rate"],
        "padded_slot_steps_steady": report["padded_slot_steps_steady"],
        "prefill_chunk": report["prefill_chunk"],
        "buckets": report["buckets"],
        "ttft_by_bucket": report["ttft_by_bucket"],
        "refused": report["refused"],
        "sample_output": first[:8],
    }
    print("[serve] done:", json.dumps(result))
    return result


if __name__ == "__main__":
    main()
