"""Token data pipeline: synthetic stream + memmap-backed dataset, per-host
sharding, background prefetch, and RESUMABLE state (step counter lives in the
checkpoint manifest, so restart replays from the exact batch).

Straggler surface: `prefetch` decouples host data work from the device step;
the StepWatchdog in launch/train.py reads the queue depth to distinguish
"data-starved" from "compute-slow" steps.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path

import numpy as np


@dataclass
class DataState:
    step: int = 0
    epoch: int = 0

    def as_dict(self):
        return {"step": self.step, "epoch": self.epoch}

    @classmethod
    def from_dict(cls, d):
        return cls(step=int(d.get("step", 0)), epoch=int(d.get("epoch", 0)))


class SyntheticTokens:
    """Deterministic synthetic LM stream: Zipf-ish marginal + shift labels.
    Deterministic in (seed, step, shard) — restart-safe by construction."""

    def __init__(self, vocab: int, batch: int, seq: int, *, seed: int = 0,
                 shard: int = 0, n_shards: int = 1):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed, self.shard, self.n_shards = seed, shard, n_shards
        assert batch % n_shards == 0

    def batch_at(self, step: int):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        b = self.batch // self.n_shards
        # Zipf-like marginal: heavier low ids (realistic token histogram)
        u = rng.random((b, self.seq + 1))
        toks = np.minimum((self.vocab * u ** 2.5).astype(np.int64),
                          self.vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class MemmapTokens:
    """Flat binary token file (uint16/uint32) -> random crops, host-sharded."""

    def __init__(self, path: str | Path, batch: int, seq: int, *,
                 dtype: str = "uint16", seed: int = 0, shard: int = 0,
                 n_shards: int = 1):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.batch, self.seq = batch, seq
        self.seed, self.shard, self.n_shards = seed, shard, n_shards
        assert len(self.data) > seq + 1, "dataset shorter than one sequence"

    def batch_at(self, step: int):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        b = self.batch // self.n_shards
        starts = rng.integers(0, len(self.data) - self.seq - 1, size=b)
        rows = np.stack([np.asarray(self.data[s:s + self.seq + 1])
                         for s in starts]).astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


class Prefetcher:
    """Background thread keeping `depth` batches ready."""

    def __init__(self, source, state: DataState, depth: int = 2):
        self.source = source
        self.state = state
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next_step = state.step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            batch = self.source.batch_at(self._next_step)
            item = (self._next_step, batch)
            self._next_step += 1
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def get(self):
        step, batch = self.q.get()
        self.state.step = step + 1
        return batch

    @property
    def depth(self) -> int:
        return self.q.qsize()

    def stop(self):
        self._stop.set()
