"""Decoder-only transformer LM (dense / MoE / VLM backbones).

Layer params are stacked along a leading L axis and iterated with lax.scan
(critical for 60-88-layer configs: HLO stays O(1) in depth); the block body is
wrapped in jax.checkpoint for training (remat policy from train/).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.tsl_api import ops as tsl

from repro.nn import flags as _nn_flags


def _scan(f, init, xs, **kw):
    return jax.lax.scan(f, init, xs, unroll=_nn_flags.scan_unroll(), **kw)


from .attention import (attention_decode, attention_forward, attention_prefill_chunk,
                        attention_span_paged, attention_verify, init_attention)
from .common import apply_norm_params, dense_init, embed_init, init_norm, split_keys
from .mlp import init_mlp, mlp_forward
from .moe import init_moe, moe_forward


def _init_block(key, cfg, dtype):
    ks = split_keys(key, 4)
    p = {
        "attn_norm": init_norm(cfg, dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "mlp_norm": init_norm(cfg, dtype),
    }
    if cfg.n_experts:
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[2], cfg, dtype)
    return p


def init_lm(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ks = split_keys(key, 4)
    block_keys = jnp.stack(split_keys(ks[0], cfg.n_layers))
    params = {
        "embed": embed_init(ks[1], (cfg.padded_vocab, cfg.d_model), dtype),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg, dtype))(block_keys),
        "final_norm": init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[2], (cfg.d_model, cfg.padded_vocab), dtype)
    if cfg.vision_prefix:
        # stub frontend's projection stands in for the ViT adapter
        params["vision_proj"] = dense_init(ks[3], (cfg.d_model, cfg.d_model), dtype)
    return params


def _block_forward(bp, x, cfg, positions):
    from repro.dist.sharding import logical_constraint
    h, kv = attention_forward(bp["attn"], apply_norm_params(cfg, bp["attn_norm"], x),
                              cfg, causal=True, positions=positions)
    x = x + h
    y = apply_norm_params(cfg, bp["mlp_norm"], x)
    if cfg.n_experts:
        y, aux = moe_forward(bp["moe"], y, cfg)
    else:
        y, aux = mlp_forward(bp["mlp"], y, cfg), jnp.float32(0)
    # pin the residual stream layout at block boundaries: stops GSPMD from
    # ping-ponging shardings between (unrolled) layers; under --sp the stream
    # is sequence-sharded on the model axis (SP-TP)
    x = logical_constraint(x + y, *_nn_flags.residual_axes())
    return x, aux, kv


def embed_inputs(params, tokens, cfg, vision_embeds=None):
    x = tsl.embed_lookup(params["embed"], tokens)
    if cfg.vision_prefix and vision_embeds is not None:
        v = tsl.matmul(vision_embeds.astype(x.dtype), params["vision_proj"])
        x = jnp.concatenate([v, x], axis=1)
    return x


def lm_forward(params, tokens, cfg, *, vision_embeds=None, remat: bool = True,
               collect_cache: bool = False, remat_policy=None,
               last_only: bool = False):
    """tokens (B,S) -> (logits (B,S_total,V), aux_loss, caches|None).

    last_only: compute logits for the final position only (prefill path —
    avoids materializing the (B,S,V) tensor)."""
    x = embed_inputs(params, tokens, cfg, vision_embeds)
    s_total = x.shape[1]
    positions = jnp.arange(s_total)

    def body(x, bp):
        xo, aux, kv = _block_forward(bp, x, cfg, positions)
        out = (aux, kv) if collect_cache else (aux, None)
        return xo, out

    if remat:
        body = jax.checkpoint(body, policy=remat_policy,
                              prevent_cse=False)
    x, (auxs, kvs) = _scan(body, x, params["blocks"])
    x = apply_norm_params(cfg, params["final_norm"], x)
    if last_only:
        x = x[:, -1:]
    logits = lm_head(params, x, cfg)
    return logits, jnp.sum(auxs), kvs


def lm_head(params, x, cfg):
    from repro.dist.sharding import logical_constraint
    if cfg.tie_embeddings:
        logits = tsl.matmul(x, params["embed"].T)
    else:
        logits = tsl.matmul(x, params["head"])
    # vocab-sharded logits: the single biggest activation — keep it TP-sharded
    # so xent's logsumexp runs shard-local + one small psum (GSPMD)
    if logits.ndim == 3:
        logits = logical_constraint(logits, "batch", None, "vocab")
    else:
        logits = logical_constraint(logits, "batch", "vocab")
    return logits


def init_decode_state(cfg, batch: int, max_len: int, dtype):
    kh, hd = cfg.n_kv_heads, cfg.hd
    shape = (cfg.n_layers, batch, kh, max_len, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def state_batch_axes(state):
    """Slot-axis position per state leaf (serve-layer state surgery): KV
    cache leaves are (L, B, KH, S_max, hd) — the request axis sits at 1."""
    return {k: 1 for k in state}


def state_page_axes(state):
    """Token-axis position per state leaf for PAGED serving (None = not
    paged): every KV leaf grows along axis 3, one row per cache token, so
    both leaves page. KV rows depend only on their absolute position (rotary
    at write time), which is what makes prefix pages exactly shareable."""
    return {k: 3 for k in state}


def lm_prefill(params, tokens, cfg, *, max_len: int, vision_embeds=None):
    """Full-sequence prefill; returns (last_logits, decode state)."""
    logits, _, kvs = lm_forward(params, tokens, cfg, vision_embeds=vision_embeds,
                                remat=False, collect_cache=True, last_only=True)
    k, v = kvs                                   # (L,B,KH,S,hd)
    pad = max_len - k.shape[3]
    if pad > 0:
        widths = ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))
        k, v = jnp.pad(k, widths), jnp.pad(v, widths)
    return logits[:, -1], {"k": k, "v": v}


def lm_prefill_chunk(params, state, tokens, pos, cfg, *, vision_embeds=None):
    """Continuation prefill of one chunk into a live decode state.

    tokens (B,C): the next chunk of the prompt; ``pos`` (scalar, may be
    traced) is the cache fill before this chunk — the chunk's K/V land at
    rows [pos, pos+C) and its queries attend causally to everything up to
    themselves. ``vision_embeds`` (vlm, first chunk only, (B,prefix,D))
    prepends the projected vision prefix rows to the chunk.

    Trailing padding rows in the chunk need no masking (see
    attention_prefill_chunk); the caller reads logits at its last real row.
    Returns (logits (B, C', V) with C' = prefix+C on the vision chunk, new
    state)."""
    x = embed_inputs(params, tokens, cfg, vision_embeds)

    def body(x_c, inp):
        bp, kc, vc = inp
        h, kc, vc = attention_prefill_chunk(
            bp["attn"], apply_norm_params(cfg, bp["attn_norm"], x_c),
            kc, vc, pos, cfg)
        x_c = x_c + h
        y = apply_norm_params(cfg, bp["mlp_norm"], x_c)
        if cfg.n_experts:
            y, _ = moe_forward(bp["moe"], y, cfg)
        else:
            y = mlp_forward(bp["mlp"], y, cfg)
        return x_c + y, (kc, vc)

    x, (k_new, v_new) = _scan(body, x, (params["blocks"], state["k"],
                                        state["v"]))
    x = apply_norm_params(cfg, params["final_norm"], x)
    logits = lm_head(params, x, cfg)
    return logits, {"k": k_new, "v": v_new}


def lm_verify_step(params, state, tokens, pos, cfg):
    """Speculative-decoding verify span. tokens (B,SV): each slot's pending
    token + drafted continuation; ``pos`` scalar or (B,) per-slot base write
    index. The span's K/V land at rows [pos, pos+SV); one ragged batched
    attention_verify scores every row (logits row j validates draft j+1).

    Rollback is free for this family: the accepted fill pos+m+1 simply stops
    short of the rejected rows, whose cache entries sit beyond kv_len where
    the decode mask hides them until overwritten. Returns
    (logits (B,SV,V), new state)."""
    x = tsl.embed_lookup(params["embed"], tokens)

    def body(x_c, inp):
        bp, kc, vc = inp
        h, kc, vc = attention_verify(
            bp["attn"], apply_norm_params(cfg, bp["attn_norm"], x_c),
            kc, vc, pos, cfg)
        x_c = x_c + h
        y = apply_norm_params(cfg, bp["mlp_norm"], x_c)
        if cfg.n_experts:
            y, _ = moe_forward(bp["moe"], y, cfg)
        else:
            y = mlp_forward(bp["mlp"], y, cfg)
        return x_c + y, (kc, vc)

    x, (k_new, v_new) = _scan(body, x, (params["blocks"], state["k"],
                                        state["v"]))
    x = apply_norm_params(cfg, params["final_norm"], x)
    logits = lm_head(params, x, cfg)
    return logits, {"k": k_new, "v": v_new}


def _lm_paged_span(params, pools, tables, tokens, pos, cfg, span_op):
    """Shared fused-paged decode/verify body: every layer's span attends
    DIRECTLY against its slice of the block-table page pools (see
    attention_span_paged) — the per-layer pool slices ride the layer scan
    as xs/ys exactly like the lane caches do, so HLO stays O(1) in depth.
    Returns (logits (B,C,V), new pools dict)."""
    x = tsl.embed_lookup(params["embed"], tokens)
    int8 = "k__scale" in pools
    xs = [params["blocks"], pools["k"], pools["v"]]
    if int8:
        xs += [pools["k__scale"], pools["v__scale"]]

    def body(x_c, inp):
        if int8:
            bp, kp, vp, ks, vs = inp
            ks, vs = ks[0], vs[0]
        else:
            bp, kp, vp = inp
            ks = vs = None
        h, kp0, vp0, ks0, vs0 = attention_span_paged(
            bp["attn"], apply_norm_params(cfg, bp["attn_norm"], x_c),
            kp[0], vp[0], tables, pos, cfg, span_op,
            k_scale=ks, v_scale=vs)
        x_c = x_c + h
        y = apply_norm_params(cfg, bp["mlp_norm"], x_c)
        if cfg.n_experts:
            y, _ = moe_forward(bp["moe"], y, cfg)
        else:
            y = mlp_forward(bp["mlp"], y, cfg)
        ys = (kp0[None], vp0[None])
        if int8:
            ys += (ks0[None], vs0[None])
        return x_c + y, ys

    x, ys = _scan(body, x, tuple(xs))
    pools = {**pools, "k": ys[0], "v": ys[1]}
    if int8:
        pools["k__scale"], pools["v__scale"] = ys[2], ys[3]
    x = apply_norm_params(cfg, params["final_norm"], x)
    return lm_head(params, x, cfg), pools


def lm_decode_step_paged(params, state, pools, tables, tokens_t, pos, cfg):
    """Fused paged decode: tokens_t (B,1) scored straight off the page
    pools via attention_decode_paged (tables (B,P) int32 page ids, ``pos``
    (B,) per-slot positions). ``state`` is the family's TAIL-only dict —
    empty for this family (every leaf pages) — passed through untouched.
    Returns (logits (B,V), state, pools)."""
    logits, pools = _lm_paged_span(params, pools, tables, tokens_t, pos, cfg,
                                   tsl.attention_decode_paged)
    return logits[:, 0], state, pools


def lm_verify_step_paged(params, state, pools, tables, tokens, pos, cfg):
    """Fused paged verify span: tokens (B,SV) score in one ragged batched
    attention_verify_paged pass; the span's K/V rows land in their pages,
    rejected rows sit beyond the committed kv_len — rollback free, exactly
    the lane-path contract. Returns (logits (B,SV,V), state, pools)."""
    logits, pools = _lm_paged_span(params, pools, tables, tokens, pos, cfg,
                                   tsl.attention_verify_paged)
    return logits, state, pools


def lm_decode_step(params, state, tokens_t, pos, cfg):
    """tokens_t (B,1); pos: scalar int32 write index, or a (B,) vector of
    per-slot indices (continuous batching — see attention_decode). Returns
    (logits (B,V), new state)."""
    x = tsl.embed_lookup(params["embed"], tokens_t)

    def body(x_t, inp):
        bp, kc, vc = inp
        h, kc, vc = attention_decode(
            bp["attn"], apply_norm_params(cfg, bp["attn_norm"], x_t),
            kc, vc, pos, cfg)
        x_t = x_t + h
        y = apply_norm_params(cfg, bp["mlp_norm"], x_t)
        if cfg.n_experts:
            y, _ = moe_forward(bp["moe"], y, cfg)
        else:
            y = mlp_forward(bp["mlp"], y, cfg)
        return x_t + y, (kc, vc)

    x, (k_new, v_new) = _scan(body, x, (params["blocks"], state["k"],
                                               state["v"]))
    x = apply_norm_params(cfg, params["final_norm"], x)
    logits = lm_head(params, x, cfg)[:, 0]
    return logits, {"k": k_new, "v": v_new}
