"""Speculative decoding on the slot table (ISSUE 7).

Covers: the longest-accepted-prefix acceptance rule (hypothesis properties:
accepted spans are prefixes, drafter==target implies full acceptance);
greedy speculative decode emitting token-for-token what plain decode emits on
all four decode families + vlm — including mid-stream slot reuse and chunked
prefill continuation under speculation; an oracle drafter driving FULL
acceptance (exercising the recurrent families' commit replay at multi-token
n_commit); k=0 exact degradation (token-for-token identical even for sampled
requests — same key draws); the engine-reported steps-per-emitted-token
dropping below 1.0 on a repetitive workload; per-request speculation
accounting (only target-emitted tokens counted); the UPD-declared span bound;
and the cost-priced depth policy's degenerate cases.
"""

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs import get_config
from repro.launch.roofline import HBM_BW
from repro.serve import (NGramDrafter, Request, ServeEngine,
                         SpeculationConfig, SpeculationPolicy, accept_span,
                         upd_verify_defaults)
from repro.serve.scheduler import CostModelAdmission

FAMILIES = [("qwen1.5-0.5b", None),    # dense lm (KV rollback)
            ("rwkv6-7b", None),        # ssm (checkpoint + commit replay)
            ("zamba2-7b", None),       # hybrid (checkpoint + commit replay)
            ("whisper-tiny", 8),       # audio encdec (KV + fixed cross K/V)
            ("internvl2-2b", None)]    # vlm (KV + vision prefix positions)

REPETITIVE = [5, 6, 7, 8, 5, 6, 7, 8, 5, 6]


def _engine_kwargs(cfg, enc_len):
    return {"enc_len": enc_len} if cfg.family == "audio" else {}


def _requests(cfg, enc_len):
    """Three requests over a 2-slot table: multi-chunk prompt (chunked
    continuation), a random prompt, and a third that must wait for a freed
    slot (mid-stream slot reuse)."""
    rnd = np.random.default_rng(0).integers(1, cfg.vocab, 5)
    reqs = [Request(rid="a", tokens=np.array(REPETITIVE), gen_len=9),
            Request(rid="b", tokens=rnd, gen_len=6),
            Request(rid="c", tokens=np.array(REPETITIVE[:7]), gen_len=8)]
    if cfg.family == "audio":
        rng = np.random.default_rng(1)
        for r in reqs:
            r.embeds = rng.standard_normal(
                (enc_len, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        rng = np.random.default_rng(1)
        for r in reqs:
            r.embeds = rng.standard_normal(
                (cfg.vision_prefix, cfg.d_model)).astype(np.float32)
    return reqs


# -- the acceptance rule (pure function, hypothesis properties) ----------------


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 6), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_accept_span_is_a_prefix(k, b, seed):
    """For arbitrary drafts/targets/windows: m <= window, every accepted
    draft matches its validating target row, and m stops at the first
    mismatch (or the window, or the full span) — never beyond."""
    rng = np.random.default_rng(seed)
    drafts = rng.integers(0, 4, (b, k))        # tiny alphabet: real matches
    target = rng.integers(0, 4, (b, k + 1))
    window = rng.integers(0, k + 3, b)
    m = accept_span(drafts, target, window)
    for i in range(b):
        mi = int(m[i])
        assert 0 <= mi <= min(window[i], k)
        assert (drafts[i, :mi] == target[i, :mi]).all()
        if mi < min(window[i], k):                  # stopped at a mismatch
            assert drafts[i, mi] != target[i, mi]


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 6), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_accept_span_full_acceptance_when_drafter_matches_target(k, b, seed):
    """drafter == target  =>  every draft inside the window is accepted."""
    rng = np.random.default_rng(seed)
    target = rng.integers(0, 50, (b, k + 1))
    window = rng.integers(0, k + 1, b)
    m = accept_span(target[:, :k], target, window)
    assert (m == np.minimum(window, k)).all()


# -- greedy speculative == plain decode, all families --------------------------


@pytest.mark.parametrize("arch,enc_len", FAMILIES)
def test_greedy_speculative_identical(arch, enc_len):
    """ISSUE 7 acceptance: greedy speculative output is identical to
    non-speculative output on every decode family — including a request
    admitted mid-stream into a reused slot (whose cache rows beyond the old
    fill hold rejected-draft garbage) and multi-chunk prefill continuation
    running while neighbours speculate."""
    import jax

    jax.clear_caches()
    cfg = get_config(arch).reduced()
    kw = _engine_kwargs(cfg, enc_len)
    plain = ServeEngine(cfg, batch=2, max_len=48, admission=False, seed=0,
                        **kw).run(_requests(cfg, enc_len))
    spec = ServeEngine(cfg, batch=2, max_len=48, admission=False, seed=0,
                       speculation=SpeculationConfig(fixed_k=3),
                       **kw).run(_requests(cfg, enc_len))
    assert plain["outputs"] == spec["outputs"]
    assert spec["spec"]["verify_steps"] > 0
    # slot reuse really happened (3 requests over 2 slots)
    assert sum(spec["slot_reuse"]) == 3
    # every request's tokens_out counts exactly its emitted tokens
    for m in spec["per_request"]:
        assert m["tokens_out"] == len(spec["outputs"][m["rid"]])


class _OracleDrafter:
    """Test-only drafter that replays the plain engine's recorded greedy
    outputs as drafts — by construction drafter == target, so every
    in-window draft must be accepted. Drives the recurrent families'
    verify_commit at multi-token n_commit."""

    def __init__(self, outputs, prompt_lens):
        self.outputs = outputs
        self.prompt_lens = prompt_lens
        self.slot_rid = {}

    def cost_per_token_s(self):
        return 0.0

    def on_chunk(self, rid, seg, n_real):
        pass

    def on_graft(self, rid, slot, history):
        self.slot_rid[slot] = rid

    def on_commit(self, slot, m):
        pass

    def on_finish(self, slot):
        pass

    def propose(self, active, histories, k_vec, batch, K):
        drafts = np.zeros((batch, K), np.int64)
        for slot in active:
            rid = self.slot_rid[slot]
            done = len(histories[slot]) - self.prompt_lens[rid]
            fut = list(self.outputs[rid][done:done + K])
            drafts[slot, :] = fut + [0] * (K - len(fut))
        return drafts


@pytest.mark.parametrize("arch,enc_len", [("qwen1.5-0.5b", None),
                                          ("rwkv6-7b", None),
                                          ("zamba2-7b", None)])
def test_oracle_drafter_fully_accepts(arch, enc_len):
    """With a drafter that proposes exactly what the target will emit, every
    verify round accepts its whole window (rate 1.0) and the engine emits
    k+1 tokens per slot-step — the per-slot steps-per-emitted-token drops to
    ~1/(k+1). On ssm/hybrid this hammers the commit replay with n_commit up
    to k+1 real rows per slot."""
    import jax

    jax.clear_caches()
    cfg = get_config(arch).reduced()
    kw = _engine_kwargs(cfg, enc_len)
    plain = ServeEngine(cfg, batch=2, max_len=48, admission=False, seed=0,
                        **kw).run(_requests(cfg, enc_len))
    eng = ServeEngine(cfg, batch=2, max_len=48, admission=False, seed=0,
                      speculation=SpeculationConfig(fixed_k=3), **kw)
    eng._drafter = _OracleDrafter(
        plain["outputs"],
        {r.rid: r.prompt_len for r in _requests(cfg, enc_len)})
    rep = eng.run(_requests(cfg, enc_len))
    assert rep["outputs"] == plain["outputs"]
    assert rep["spec"]["accepted_rate"] == 1.0
    assert rep["spec"]["slot_steps_per_emitted_token"] < 0.5
    assert rep["spec"]["accept_by_bucket"]
    for stats in rep["spec"]["accept_by_bucket"].values():
        assert stats["accepted_rate"] == 1.0
        assert stats["mean_accepted_span"] > 1.0


# -- k = 0 degrades to exactly today's decode ----------------------------------


@pytest.mark.parametrize("arch,enc_len", FAMILIES[:4])
def test_k0_is_token_for_token_identical(arch, enc_len):
    """fixed_k=0 runs the ORIGINAL decode path (same jitted fn, same sampler
    call, same key draws): outputs are identical to the plain engine even
    for SAMPLED requests — mixed greedy/sampled in one batch."""
    import jax

    jax.clear_caches()
    cfg = get_config(arch).reduced()
    kw = _engine_kwargs(cfg, enc_len)

    def mk():
        reqs = _requests(cfg, enc_len)
        reqs[1].temperature = 0.9           # one sampled slot amid greedy
        return reqs

    plain = ServeEngine(cfg, batch=2, max_len=48, admission=False, seed=0,
                        **kw).run(mk())
    spec = ServeEngine(cfg, batch=2, max_len=48, admission=False, seed=0,
                       speculation=SpeculationConfig(fixed_k=0),
                       **kw).run(mk())
    assert plain["outputs"] == spec["outputs"]
    assert spec["spec"]["verify_steps"] == 0
    assert spec["spec"]["decode_steps"] > 0
    assert spec["spec"]["slot_steps_per_emitted_token"] == 1.0


# -- the speedup headline ------------------------------------------------------


def test_steps_per_emitted_token_below_one_on_repetitive_workload():
    """ISSUE 7 acceptance: on a repetitive workload the engine-reported
    decode steps per emitted token drops below 1.0 (both the raw and the
    batching-independent per-slot variant)."""
    import jax

    jax.clear_caches()
    cfg = get_config("qwen1.5-0.5b").reduced()
    rep = ServeEngine(
        cfg, batch=2, max_len=48, admission=False, seed=0,
        speculation=SpeculationConfig(fixed_k=3)).run(
            [Request(rid="a", tokens=np.array(REPETITIVE), gen_len=12),
             Request(rid="c", tokens=np.array(REPETITIVE[:7]), gen_len=10)])
    assert rep["spec"]["accepted_rate"] > 0
    assert rep["spec"]["steps_per_emitted_token"] < 1.0
    assert rep["spec"]["slot_steps_per_emitted_token"] < 1.0
    # decode-t/s denominators count only target-emitted tokens
    for m in rep["per_request"]:
        assert m["tokens_out"] == len(rep["outputs"][m["rid"]])
        assert m["spec_proposed"] >= m["spec_accepted"]


# -- drafters ------------------------------------------------------------------


def test_ngram_drafter_prompt_lookup():
    """The n-gram drafter continues the longest matched suffix from its
    earlier occurrence (prompt-lookup decoding), falling back to
    repeat-last."""
    d = NGramDrafter(max_ngram=3)
    # suffix [7, 8] occurred earlier, followed by [9, 1]
    hist = np.array([5, 7, 8, 9, 1, 2, 7, 8])
    assert d._continue(hist, 2) == [9, 1]
    assert d._continue(hist, 4) == [9, 1, 2, 7]
    # no recurrence: repeat the last token
    assert d._continue(np.array([1, 2, 3]), 3) == [3, 3, 3]
    # batched proposal fills only slots with a positive window
    drafts = d.propose([0], {0: [5, 6, 5, 6], 1: [9]},
                       np.array([2, 0]), 2, 2)
    assert drafts[0].tolist() == [5, 6]
    assert drafts[1].tolist() == [0, 0]


# -- UPD span bound + cost-priced depth ----------------------------------------


def test_k_max_comes_from_upd_serve_block():
    d = upd_verify_defaults()
    assert d["k_max"] == 4
    cfg = get_config("qwen1.5-0.5b").reduced()
    eng = ServeEngine(cfg, batch=2, max_len=48, admission=False,
                      speculation=SpeculationConfig())
    assert eng._k_max == d["k_max"]
    # the slot table carries k_max headroom rows for neighbour-depth slabs
    assert eng._state_len == 48 + d["k_max"]


def test_policy_depth_degenerate_and_priced():
    cfg = get_config("qwen1.5-0.5b").reduced()
    cm = CostModelAdmission(cfg, batch=2, max_len=48)
    pol = SpeculationPolicy(2, 4, cm, SpeculationConfig(ema_init=0.6))
    # last token of the budget: never draft past gen_len
    assert pol.depth(0, fill=10, remaining=1) == 0
    # fixed_k clips to both k_max and the remaining budget
    fixed = SpeculationPolicy(2, 4, cm, SpeculationConfig(fixed_k=3))
    assert fixed.depth(0, fill=10, remaining=10) == 3
    assert fixed.depth(0, fill=10, remaining=3) == 2
    # priced: verify at span k+1 is far cheaper than k+1 decode steps
    # (param bytes stream once), so a confident EMA chooses k > 0
    assert pol.depth(0, fill=10, remaining=10) > 0
    # a hopeless EMA degrades to plain decode
    pol.alpha[1] = 0.0
    assert pol.depth(1, fill=10, remaining=10) == 0
    # EMA update moves toward the observed acceptance
    a0 = pol.alpha[0]
    pol.update(0, proposed=4, accepted=0)
    assert pol.alpha[0] < a0
    pol.update(0, proposed=4, accepted=4)
    assert pol.alpha[0] > pol.alpha[1]


def test_verify_seconds_pricing():
    """verify_seconds grows with span width, recurrent families pay the
    commit factor, and admission's best-case per-token price never exceeds
    the plain decode step."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    cm = CostModelAdmission(cfg, batch=2, max_len=48)
    v1, v4 = cm.verify_seconds(1), cm.verify_seconds(4)
    assert 0 < v1 < v4
    # a fully-accepted span of 5 beats 5 decode steps by a wide margin
    assert v4 / 5 < cm.step_seconds()
    cm.spec_k = 4
    assert cm.emit_seconds_per_token() <= cm.step_seconds()
    # recurrent: commit replay doubles the verify price
    rcfg = get_config("rwkv6-7b").reduced()
    rcm = CostModelAdmission(rcfg, batch=2, max_len=48)
    assert rcm.verify_seconds(2) == pytest.approx(
        2.0 * rcm.param_bytes / HBM_BW)
