"""rwkv6-7b [ssm]: Finch — attention-free, data-dependent decay.

32L d_model=4096 (attn-free) d_ff=14336 vocab=65536
[arXiv:2404.05892; hf]

64 WKV heads of size 64 (d_model/64). flash_attention is inapplicable to this
arch (DESIGN.md §4) — sequence mixing is the wkv6_scan TSL primitive.
"""

from .arch import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,                  # wkv heads = d_model / rwkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    rwkv_head_dim=64,
    norm="layernorm",
    norm_eps=1e-5,
    source="arXiv:2404.05892; hf",
)
