"""Public generator API: generate → materialize on disk → import.

The C++ TSL is generated into a header tree and compiled into the consumer;
the JAX analogue generates a Python package into ``build/tsl/`` and imports
it. The package directory name embeds target + UPD fingerprint + cherry-pick
hash, so regeneration is a cache hit when nothing changed (paper Fig 7a:
cmake re-runs the generator; dependency tracking makes it cheap).
"""

from __future__ import annotations

import hashlib
import importlib
import sys
from pathlib import Path
from types import ModuleType

from . import hwprobe, loader
from .model import Context, GenConfig
from .pipeline import core_pipeline

DEFAULT_BUILD_ROOT = Path(__file__).resolve().parents[3] / "build" / "tsl"

_IN_PROCESS_CACHE: dict[str, ModuleType] = {}


def _pkg_name(config: GenConfig, fingerprint: str) -> str:
    h = hashlib.sha256()
    h.update(fingerprint.encode())
    h.update(repr(sorted(config.only) if config.only else None).encode())
    h.update(repr(config.hardware_flags).encode())
    h.update(repr((config.emit_tests, config.emit_docs, config.emit_build,
                   config.use_bench_selection)).encode())
    return f"{config.package_name}_{config.target}_{h.hexdigest()[:10]}"


def generate_library(config: GenConfig, build_root: Path | None = None,
                     *, force: bool = False) -> tuple[Path, Context | None]:
    """Run the pipeline and write the generated package. Returns (pkg_dir, ctx);
    ctx is None on a disk-cache hit."""
    build_root = Path(build_root or DEFAULT_BUILD_ROOT)
    fingerprint = loader.upd_fingerprint(config.upd_paths)
    pkg = _pkg_name(config, fingerprint)
    pkg_dir = build_root / pkg
    stamp = pkg_dir / "_manifest.json"
    if stamp.exists() and not force:
        return pkg_dir, None

    config = GenConfig(**{**config.__dict__, "package_name": pkg})
    ctx = core_pipeline(config).run(config)
    pkg_dir.mkdir(parents=True, exist_ok=True)
    for f in ctx.files:
        out = pkg_dir / f.relpath
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(f.content)
    if not (pkg_dir / "_manifest.json").exists():
        # emit_build=False still needs the cache stamp
        (pkg_dir / "_manifest.json").write_text("{}")
    return pkg_dir, ctx


def load_library(target: str = "auto", *, only: tuple[str, ...] | None = None,
                 hardware_flags: tuple[str, ...] | None = None,
                 emit_tests: bool = True, emit_docs: bool = False,
                 use_bench_selection: bool = False,
                 upd_paths: tuple[str, ...] = (),
                 build_root: Path | None = None,
                 force: bool = False) -> ModuleType:
    """Generate (cached) and import the TSL for ``target``.

    ``target='auto'`` probes the live backend (paper: cpuinfo flags feeding
    the generator from cmake)."""
    if target == "auto":
        target = hwprobe.live_target()
    config = GenConfig(
        target=target,
        hardware_flags=hardware_flags,
        only=tuple(only) if only else None,
        emit_tests=emit_tests,
        emit_docs=emit_docs,
        use_bench_selection=use_bench_selection,
        upd_paths=tuple(upd_paths),
    )
    build_root = Path(build_root or DEFAULT_BUILD_ROOT)
    pkg_dir, _ = generate_library(config, build_root, force=force)
    pkg = pkg_dir.name
    if pkg in _IN_PROCESS_CACHE and not force:
        return _IN_PROCESS_CACHE[pkg]
    if str(build_root) not in sys.path:
        sys.path.insert(0, str(build_root))
    if force and pkg in sys.modules:
        for m in [m for m in sys.modules if m == pkg or m.startswith(pkg + ".")]:
            del sys.modules[m]
    mod = importlib.import_module(pkg)
    _IN_PROCESS_CACHE[pkg] = mod
    return mod
