"""CLI for the incremental multi-target generation engine.

    python -m repro.core generate --targets cpu_xla,pallas_interpret
    python -m repro.core generate --all --force
    python -m repro.core corpus
    python -m repro.core analyze --fail-on=error --format=json
    python -m repro.core bench --report bench-report.json
    python -m repro.core bench --smoke
    python -m repro.core cache stats
    python -m repro.core cache clear
    python -m repro.core cache gc --max-age-days 30

The paper drives its generator from a ``main.py`` invoked by cmake; this is
the JAX-analogue entry point, plus artifact-cache maintenance and the §4.2
"ongoing process" bench sweep that warms measured block-size/variant winners
for every host-runnable target under the probed hardware key.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _add_common(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--upd-path", action="append", default=[],
                    help="extra UPD search path (repeatable)")
    ap.add_argument("--build-root", default=None,
                    help="artifact cache root (default: build/tsl)")


def _cmd_generate(args) -> int:
    from .corpus import load_corpus
    from .library import generate_all

    upd_paths = tuple(args.upd_path)
    corpus = load_corpus(upd_paths)
    if args.all:
        targets = None
    elif args.targets:
        targets = [t for chunk in args.targets for t in chunk.split(",") if t]
    else:
        print("error: pass --targets a,b,... or --all", file=sys.stderr)
        return 2
    out = generate_all(
        targets,
        Path(args.build_root) if args.build_root else None,
        force=args.force,
        corpus=corpus,
        upd_paths=upd_paths,
        only=tuple(args.only) if args.only else None,
        emit_docs=args.docs,
        use_bench_selection=args.bench,
    )
    for name, pkg_dir in out.items():
        print(f"{name}: {pkg_dir}")
    return 0


def _cmd_corpus(args) -> int:
    from .corpus import load_corpus

    corpus = load_corpus(tuple(args.upd_path))
    info = {
        "fingerprint": corpus.fingerprint,
        "targets": sorted(corpus.targets),
        "primitives": len(corpus.primitives),
        "warnings": len(corpus.warnings),
    }
    print(json.dumps(info, indent=1))
    if args.warnings:
        for w in corpus.warnings:
            print(f"  warning: {w}")
    return 0


def _repo_root() -> Path:
    # src/repro/core/cli.py -> src/repro/core -> src/repro -> src -> repo
    return Path(__file__).resolve().parents[3]


def _diff_bench_winners(trajectory: dict, fresh: dict) -> list[str]:
    """Selection regressions between a checked-in bench trajectory and a
    fresh sweep of the same target. The benched SURFACE (which
    primitive/ctype pairs are benched, with which candidate sets) must match
    exactly — a mismatch means the corpus changed without refreshing the
    trajectory. A WINNER change only fails when the fresh measurement shows
    the recorded winner clearly losing (>= 1.5x slower than the new winner):
    near-ties flip on timing noise and must not flake CI."""
    problems: list[str] = []
    old, new = trajectory.get("winners", {}), fresh.get("winners", {})
    for key in sorted(set(old) | set(new)):
        if key not in new:
            problems.append(f"{key}: benched in trajectory, not benched now")
            continue
        if key not in old:
            problems.append(f"{key}: newly benched; refresh the trajectory "
                            "(python -m repro.core bench --report)")
            continue
        o, n = old[key], new[key]
        if o["candidates"] != n["candidates"]:
            problems.append(f"{key}: candidate set changed "
                            f"{o['candidates']} -> {n['candidates']}; "
                            "refresh the trajectory")
            continue
        if o["winner"] == n["winner"]:
            continue
        times = dict(zip(n["candidates"], n["times_us"]))
        t_old, t_new = times.get(o["winner"]), times.get(n["winner"])
        if t_old is not None and t_new is not None and t_old >= 1.5 * t_new:
            problems.append(
                f"{key}: winner def[{o['winner']}] -> def[{n['winner']}] "
                f"({t_old:.0f}us vs {t_new:.0f}us, >=1.5x margin)")
        else:
            print(f"bench-diff: {key}: winner flipped "
                  f"def[{o['winner']}] -> def[{n['winner']}] within noise "
                  "margin; not failing", file=sys.stderr)
    return problems


def _cmd_bench(args) -> int:
    """Warm bench-selection winners for every host-runnable target and emit a
    JSON report of winners per (target, primitive, hardware key)."""
    from .corpus import load_corpus
    from .library import (DEFAULT_BUILD_ROOT, artifact_key, generate_library)
    from .cache import ArtifactCache
    from .model import GenConfig

    upd_paths = tuple(args.upd_path)
    corpus = load_corpus(upd_paths)
    if args.targets:
        names = [t for chunk in args.targets for t in chunk.split(",") if t]
        unknown = sorted(set(names) - set(corpus.targets))
        if unknown:
            print(f"error: unknown target(s) {unknown}", file=sys.stderr)
            return 2
        not_host = [t for t in names if not corpus.targets[t].runs_on_host]
        if not_host:
            print(f"error: target(s) {not_host} do not run on this host",
                  file=sys.stderr)
            return 2
    else:
        names = [t for t in sorted(corpus.targets)
                 if corpus.targets[t].runs_on_host]
    build_root = Path(args.build_root) if args.build_root else DEFAULT_BUILD_ROOT
    store = ArtifactCache(build_root)
    report: dict = {"smoke": args.smoke, "targets": {}}
    for name in names:
        cfg = GenConfig(target=name, upd_paths=upd_paths,
                        use_bench_selection=True, bench_smoke=args.smoke)
        # force: the sweep's job is to (re-)measure, not to hit the package
        # cache; already-measured winners are still reused from the bench store
        _, res = generate_library(cfg, build_root, force=True, corpus=corpus)
        key = artifact_key(cfg, corpus.fingerprint, corpus)
        winners = store.bench_load(key)
        report["targets"][name] = {
            "hardware_flags": list(key.hardware_flags),
            "bench_entry": store.bench_path(key).name,
            "winners": winners,
            "warnings": [w for w in (res.warnings if res else [])
                         if "bench" in w],
        }
    print(json.dumps(report, indent=1))
    if args.report == "__root__":
        # commit the bench trajectory: one BENCH_<target>.json per swept
        # target at the repo root, so selection changes show up in review
        for name, entry in report["targets"].items():
            out = _repo_root() / f"BENCH_{name}.json"
            out.write_text(json.dumps(
                {"target": name, "smoke": args.smoke, **entry}, indent=1)
                + "\n")
            print(f"trajectory: {out}", file=sys.stderr)
    elif args.report:
        Path(args.report).write_text(json.dumps(report, indent=1))
    if args.diff:
        trajectory = json.loads(Path(args.diff).read_text())
        tgt = trajectory.get("target")
        if tgt not in report["targets"]:
            print(f"error: trajectory target {tgt!r} was not swept",
                  file=sys.stderr)
            return 2
        problems = _diff_bench_winners(trajectory, report["targets"][tgt])
        for p in problems:
            print(f"bench-diff: REGRESSION {p}", file=sys.stderr)
        if problems:
            return 1
        print(f"bench-diff: winners match {args.diff}", file=sys.stderr)
    return 0


def _cmd_analyze(args) -> int:
    """TSL-Check: semantic static analysis over the validated corpus, the
    cost channel, and the Pallas kernels (stable TSL0xx finding codes)."""
    from .corpus import load_corpus
    from repro.analyze import run_analysis

    corpus = load_corpus(tuple(args.upd_path))
    roots = tuple(Path(p) for p in args.kernels_root) if args.kernels_root \
        else None
    rep = run_analysis(corpus, kernel_roots=roots)

    baseline = Path(args.baseline) if args.baseline else None
    if args.update_baseline:
        if baseline is None:
            print("error: --update-baseline requires --baseline PATH",
                  file=sys.stderr)
            return 2
        idents = sorted({f.identity() for f in rep.active_findings()})
        baseline.write_text("\n".join(idents) + ("\n" if idents else ""))
        print(f"baseline: {len(idents)} finding identit(ies) -> {baseline}")
        return 0
    if baseline is not None and baseline.exists():
        known = {ln.strip() for ln in baseline.read_text().splitlines()
                 if ln.strip()}
        rep.apply_baseline(known)

    if args.report:
        out = Path(args.report)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.with_suffix(".json").write_text(rep.to_json_str() + "\n")
        out.with_suffix(".md").write_text(rep.to_markdown() + "\n")
    print(rep.to_json_str() if args.format == "json" else rep.to_text())
    return rep.exit_code(args.fail_on)


def _cmd_cache(args) -> int:
    from .cache import ArtifactCache
    from .library import DEFAULT_BUILD_ROOT

    store = ArtifactCache(Path(args.build_root) if args.build_root
                          else DEFAULT_BUILD_ROOT)
    if args.action == "stats":
        print(json.dumps(store.stats(), indent=1))
    elif args.action == "gc":
        if args.max_age_days is None:
            print("error: cache gc requires --max-age-days N", file=sys.stderr)
            return 2
        print(f"removed {store.gc(args.max_age_days)} expired artifact(s)")
    else:  # clear
        print(f"removed {store.clear()} cached artifact(s)")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.core",
                                 description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="command", required=True)

    g = sub.add_parser("generate", help="generate libraries for target(s)")
    _add_common(g)
    g.add_argument("--targets", action="append", default=[],
                   help="comma-separated target names (repeatable)")
    g.add_argument("--all", action="store_true",
                   help="every target the corpus defines")
    g.add_argument("--only", action="append", default=[],
                   help="cherry-picked primitive (repeatable; paper 'slim')")
    g.add_argument("--force", action="store_true",
                   help="regenerate even on a cache hit")
    g.add_argument("--bench", action="store_true",
                   help="benchmark-driven adaptive selection (paper §4.2)")
    g.add_argument("--docs", action="store_true", help="emit docs/ markdown")
    g.set_defaults(fn=_cmd_generate)

    c = sub.add_parser("corpus", help="validate + summarize the UPD corpus")
    _add_common(c)
    c.add_argument("--warnings", action="store_true",
                   help="print every corpus warning")
    c.set_defaults(fn=_cmd_corpus)

    b = sub.add_parser(
        "bench", help="warm bench-selection winners for host-runnable targets")
    _add_common(b)
    b.add_argument("--targets", action="append", default=[],
                   help="comma-separated host-runnable targets "
                        "(default: every runs_on_host target)")
    b.add_argument("--report", nargs="?", const="__root__", default=None,
                   help="write the JSON winners report: with PATH, one "
                        "combined file there; bare, one BENCH_<target>.json "
                        "trajectory per target at the repo root (check in)")
    b.add_argument("--diff", default=None,
                   help="compare this sweep's winners against a checked-in "
                        "BENCH_<target>.json trajectory; exit 1 on a clear "
                        "selection regression")
    b.add_argument("--smoke", action="store_true",
                   help="single-iteration smoke sweep (CI: exercises the "
                        "benchgen path without the measurement cost)")
    b.set_defaults(fn=_cmd_bench)

    a = sub.add_parser(
        "analyze", help="TSL-Check: semantic static analysis (TSL0xx codes)")
    _add_common(a)
    a.add_argument("--fail-on", choices=("error", "warn", "info", "never"),
                   default="error",
                   help="lowest severity that makes the exit code nonzero")
    a.add_argument("--format", choices=("text", "json"), default="text")
    a.add_argument("--report", default=None,
                   help="write <path>.json and <path>.md report files")
    a.add_argument("--baseline", default=None,
                   help="accepted-findings file: listed identities do not "
                        "gate the exit code")
    a.add_argument("--update-baseline", action="store_true",
                   help="rewrite --baseline with the current findings")
    a.add_argument("--kernels-root", action="append", default=[],
                   help="extra kernel tree to lint (default: repro.kernels)")
    a.set_defaults(fn=_cmd_analyze)

    k = sub.add_parser("cache", help="artifact-cache maintenance")
    _add_common(k)
    k.add_argument("action", choices=("stats", "clear", "gc"))
    k.add_argument("--max-age-days", type=float, default=None,
                   help="gc: evict artifacts older than this many days")
    k.set_defaults(fn=_cmd_cache)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
