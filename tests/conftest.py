import pytest

# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.

# Property tests prefer real hypothesis; on containers without it, a seeded
# deterministic stub keeps them runnable instead of erroring at collection.
from repro._compat import hypothesis_stub as _hypothesis_stub

_hypothesis_stub._register()


@pytest.fixture(scope="session")
def lib_cpu():
    from repro.core import load_library

    return load_library("cpu_xla")


@pytest.fixture(scope="session")
def lib_interp():
    from repro.core import load_library

    return load_library("pallas_interpret")


@pytest.fixture(scope="session")
def host_mesh():
    import jax

    return jax.make_mesh((1, 1), ("data", "model"))
