"""Finding model + stable code registry for TSL-Check (the semantic
static-analysis GPO).

The paper claims the generator "exposes valuable insights for assessing
provided functionality"; ``ValidateGPO`` only schema-checks. TSL-Check is the
semantic layer on top: every rule has a stable ``TSL0xx`` code, a fixed
severity, and a one-line rationale, so findings are machine-diffable across
PRs (CI uploads the JSON report) and suppressible per UPD document.

Code space (documented for users in ``tsl_data/README.md``):

* ``TSL00x`` — corpus plumbing (schema errors surfaced through analysis)
* ``TSL01x`` — cost channel (formulas the serving scheduler prices with)
* ``TSL02x`` — coverage matrix (primitive × target × ctype insights)
* ``TSL03x`` — Pallas tiling (BlockSpec/grid geometry vs the target SRU)
* ``TSL04x`` — implementation-body safety (UPD code that is exec'd/traced)
"""

from __future__ import annotations

import json
from dataclasses import dataclass, replace

SEVERITIES = ("error", "warn", "info")


@dataclass(frozen=True)
class Code:
    code: str
    severity: str            # "error" | "warn" | "info"
    title: str
    rationale: str


_CODE_LIST = (
    # -- corpus plumbing ----------------------------------------------------
    Code("TSL001", "error", "corpus validation error",
         "The UPD failed schema validation; analysis ran on the surviving "
         "documents only."),
    Code("TSL002", "info", "corpus validation warning",
         "ValidateGPO emitted a warning for this document."),
    # -- cost channel -------------------------------------------------------
    Code("TSL010", "error", "cost formula does not parse",
         "The serving scheduler eval()s this formula for admission; a syntax "
         "error becomes a runtime crash in the serving path."),
    Code("TSL011", "error", "cost formula uses a non-whitelisted construct",
         "Cost formulas are restricted to names, numeric literals and "
         "arithmetic (+ - * / // % ** and unary minus); calls, attributes, "
         "subscripts or comparisons would execute arbitrary code inside the "
         "generated library's cost() eval."),
    Code("TSL012", "error", "cost formula references an undeclared shape symbol",
         "Every free symbol must appear in the primitive's cost_shapes "
         "declaration; an unbound symbol raises NameError the first time the "
         "scheduler prices this primitive."),
    Code("TSL013", "warn", "cost formulas present but no cost_shapes declared",
         "Without a cost_shapes declaration the symbol-binding check cannot "
         "run; callers can only discover the expected shape keywords by "
         "reading the formula."),
    Code("TSL014", "error", "priced primitive missing flops/bytes cost term",
         "The serving scheduler prices admission with this primitive's "
         "flops+bytes terms; a missing term silently falls back to an "
         "analytic guess at runtime (serve/scheduler.py logs this code)."),
    Code("TSL015", "info", "benchmarked primitive carries no cost metadata",
         "bench-selection measures this primitive but no cost formula is "
         "recorded, so rooflines cannot cross-check measured vs predicted."),
    # -- coverage matrix ----------------------------------------------------
    Code("TSL020", "info", "asymmetric target coverage",
         "The primitive is generatable for some targets but not others; a "
         "library generated for an uncovered target silently omits it."),
    Code("TSL021", "warn", "primitive has no test cases",
         "Paper §4.1: untested primitives ship ungated; every definition "
         "should carry at least one co-located test."),
    Code("TSL022", "warn", "definition requires flags no target provides",
         "hwprobe can only ever produce flags declared by some SRU document; "
         "a definition gated on an unknown flag is dead code in every "
         "generated library."),
    Code("TSL023", "warn", "definition is never selectable (dead candidate)",
         "On every (target, ctype) either the flag heuristic picks another "
         "definition and no bench: setup exists to overrule it, or the "
         "definition is invalid — it can never appear in a generated "
         "library."),
    Code("TSL024", "warn", "definition ctype not offered by its target",
         "The target SRU does not list this element type, so the "
         "specialization is unreachable through dispatch."),
    # -- Pallas tiling ------------------------------------------------------
    Code("TSL030", "warn", "BlockSpec block shape misaligned to target tiling",
         "Constant block dims should be multiples of the SRU's (sublanes, "
         "lanes) vector-register geometry; misaligned tiles force Mosaic "
         "relayouts or fail to lower on real TPUs."),
    Code("TSL031", "warn", "unguarded grid remainder (floor division)",
         "A grid computed with // silently drops the remainder rows unless "
         "the module also guards (x % b) or uses a ceil-div; pad the input "
         "or guard the divisibility."),
    Code("TSL032", "warn", "reduction may accumulate below float32",
         "dot/dot_general/einsum without preferred_element_type= accumulates "
         "in the input dtype — bf16 MXU accumulation loses ~8 bits per "
         "256-term sum."),
    Code("TSL033", "warn", "page-size candidate misaligned to a target's "
         "sublane tiling",
         "cache_page_read/write gather whole pages as (page, row) slabs; a "
         "page size that is not a positive multiple of a covered target's "
         "SRU sublanes forces Mosaic relayouts on every gather and wastes "
         "VREG rows on every scatter."),
    # -- implementation-body safety -----------------------------------------
    Code("TSL040", "error", "implementation body fails to render or parse",
         "Definition bodies are stage-1 Jinja templates that must render to "
         "valid Python; this one would break generation for its target."),
    Code("TSL041", "error", "host numpy (np.) used in a traced body",
         "Implementation bodies run under jit; np.* calls either fail to "
         "trace or silently fall back to host execution — use jnp."),
    Code("TSL042", "error", "I/O or host side effect in a traced body",
         "print/open/os/sys/subprocess inside a generated implementation "
         "executes at trace time (at best once, at worst never) and makes "
         "the artifact non-reproducible."),
    Code("TSL043", "error", "host callback primitive in a traced body",
         "pure_callback/io_callback/debug.callback punch through the "
         "compiled graph; the generated TSL must stay device-only."),
    Code("TSL044", "error", "nondeterminism in a traced body",
         "time.*/random.*/np.random.* make regeneration non-reproducible "
         "and break the content-addressed artifact cache contract."),
)

CODES: dict[str, Code] = {c.code: c for c in _CODE_LIST}


@dataclass(frozen=True)
class Finding:
    """One analyzer finding, attributable to a stable ``TSL0xx`` code.

    ``subject`` is a stable coordinate (``primitive:name``, ``target:name`` or
    ``file:relpath``); ``location`` is a human refinement (``def[2]``,
    ``line 57``) that deliberately does NOT participate in baseline identity,
    so unrelated edits shifting a line never churn the baseline.
    """

    code: str
    message: str
    subject: str = ""
    location: str = ""
    suppressed: bool = False      # per-document lint: {suppress: [...]} hit
    baselined: bool = False       # accepted via --baseline file

    @property
    def severity(self) -> str:
        return CODES[self.code].severity

    @property
    def active(self) -> bool:
        return not (self.suppressed or self.baselined)

    def identity(self) -> str:
        return f"{self.code} {self.subject}"

    def render(self) -> str:
        loc = f" ({self.location})" if self.location else ""
        tag = " [suppressed]" if self.suppressed else (
            " [baselined]" if self.baselined else "")
        return f"{self.code} {self.severity}: {self.subject}{loc}: {self.message}{tag}"


class AnalysisReport:
    """Aggregated findings + rendering (docgen-style markdown, JSON, text)."""

    def __init__(self, findings: list[Finding] | None = None):
        self.findings: list[Finding] = list(findings or [])

    def add(self, code: str, message: str, *, subject: str = "",
            location: str = "") -> None:
        if code not in CODES:
            raise KeyError(f"unknown finding code {code!r}")
        self.findings.append(Finding(code=code, message=message,
                                     subject=subject, location=location))

    def extend(self, other: "AnalysisReport") -> None:
        self.findings.extend(other.findings)

    # -- suppression / baseline --------------------------------------------

    def apply_suppressions(self, suppressed_for) -> None:
        """``suppressed_for(finding) -> bool`` marks per-document
        ``lint: {suppress: [...]}`` hits (kept in the report, not counted)."""
        self.findings = [
            replace(f, suppressed=True) if (not f.suppressed and suppressed_for(f))
            else f
            for f in self.findings
        ]

    def apply_baseline(self, identities: set[str]) -> None:
        self.findings = [
            replace(f, baselined=True)
            if (f.active and f.identity() in identities) else f
            for f in self.findings
        ]

    # -- aggregation ---------------------------------------------------------

    def active_findings(self) -> list[Finding]:
        return [f for f in self.findings if f.active]

    def counts(self) -> dict[str, int]:
        out = {s: 0 for s in SEVERITIES}
        for f in self.active_findings():
            out[f.severity] += 1
        out["suppressed"] = sum(f.suppressed for f in self.findings)
        out["baselined"] = sum(f.baselined for f in self.findings)
        return out

    def codes(self) -> set[str]:
        return {f.code for f in self.active_findings()}

    def exit_code(self, fail_on: str = "error") -> int:
        """0 unless an active finding is at/above the ``fail_on`` severity."""
        if fail_on == "never":
            return 0
        gate = {"error": ("error",), "warn": ("error", "warn"),
                "info": SEVERITIES}[fail_on]
        return 1 if any(f.severity in gate for f in self.active_findings()) else 0

    def sorted_findings(self) -> list[Finding]:
        rank = {s: i for i, s in enumerate(SEVERITIES)}
        return sorted(self.findings,
                      key=lambda f: (rank[f.severity], f.code, f.subject,
                                     f.location))

    # -- rendering -----------------------------------------------------------

    def to_json(self) -> dict:
        return {
            "counts": self.counts(),
            "findings": [
                {
                    "code": f.code,
                    "severity": f.severity,
                    "subject": f.subject,
                    "location": f.location,
                    "message": f.message,
                    "suppressed": f.suppressed,
                    "baselined": f.baselined,
                }
                for f in self.sorted_findings()
            ],
        }

    def to_json_str(self) -> str:
        return json.dumps(self.to_json(), indent=1)

    def to_markdown(self) -> str:
        counts = self.counts()
        lines = [
            "# TSL-Check findings",
            "",
            f"**{counts['error']} error(s), {counts['warn']} warning(s), "
            f"{counts['info']} info** "
            f"({counts['suppressed']} suppressed, {counts['baselined']} baselined)",
            "",
        ]
        by_code: dict[str, list[Finding]] = {}
        for f in self.sorted_findings():
            by_code.setdefault(f.code, []).append(f)
        for code in sorted(by_code):
            meta = CODES[code]
            lines += [f"## `{code}` — {meta.title} ({meta.severity})", "",
                      meta.rationale, "",
                      "| subject | location | message | state |",
                      "|---|---|---|---|"]
            for f in by_code[code]:
                state = ("suppressed" if f.suppressed
                         else "baselined" if f.baselined else "active")
                lines.append(
                    f"| {f.subject} | {f.location or '—'} | {f.message} | {state} |")
            lines.append("")
        if not by_code:
            lines.append("No findings — the corpus lints clean.")
        return "\n".join(lines)

    def to_text(self) -> str:
        out = [f.render() for f in self.sorted_findings()]
        c = self.counts()
        out.append(
            f"{c['error']} error(s), {c['warn']} warning(s), {c['info']} info, "
            f"{c['suppressed']} suppressed, {c['baselined']} baselined")
        return "\n".join(out)


__all__ = ["AnalysisReport", "CODES", "Code", "Finding", "SEVERITIES"]
