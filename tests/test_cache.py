"""Incremental-engine tests: corpus memoisation (validate once per
fingerprint), artifact-cache warm paths (no GPO re-runs), and unified
invalidation keyed on (UPD fingerprint, hardware flags, generator version)
— ISSUE 2 acceptance criteria."""

import textwrap

import pytest

from repro.core import (GenConfig, corpus_cache_clear, generate_all,
                        generate_library, load_library)
from repro.core.generate import GenerateGPO
from repro.core.validate import ValidateGPO


@pytest.fixture()
def counted(monkeypatch):
    """Count ValidateGPO/GenerateGPO invocations via class-level patches."""
    counts = {"validate": 0, "generate": 0}
    real_validate = ValidateGPO.run
    real_generate = GenerateGPO.run

    def count_validate(self, ctx):
        counts["validate"] += 1
        return real_validate(self, ctx)

    def count_generate(self, ctx):
        counts["generate"] += 1
        return real_generate(self, ctx)

    monkeypatch.setattr(ValidateGPO, "run", count_validate)
    monkeypatch.setattr(GenerateGPO, "run", count_generate)
    return counts


def test_generate_all_validates_once(tmp_path, counted):
    """Regenerating a SECOND (and third) target from a warm corpus performs
    zero re-validation — the corpus phase ran exactly once."""
    corpus_cache_clear()
    out = generate_all(["cpu_xla", "pallas_interpret", "gpu_pallas"],
                       tmp_path, force=True)
    assert set(out) == {"cpu_xla", "pallas_interpret", "gpu_pallas"}
    for pkg_dir in out.values():
        assert (pkg_dir / "_manifest.json").exists()
    assert counted["validate"] == 1
    assert counted["generate"] == 3


def test_load_library_warm_path_runs_no_gpo(tmp_path, counted):
    """Repeated load_library() with unchanged fingerprint + hardware flags is
    served from the artifact cache: GenerateGPO does not re-run."""
    lib1 = load_library("cpu_xla", build_root=tmp_path)
    generated_after_cold = counted["generate"]
    assert generated_after_cold == 1
    lib2 = load_library("cpu_xla", build_root=tmp_path)
    assert counted["generate"] == generated_after_cold    # warm: zero re-runs
    assert lib2 is lib1


def _upd(root, flag="v1"):
    (root / "targets").mkdir(parents=True, exist_ok=True)
    (root / "primitives").mkdir(parents=True, exist_ok=True)
    (root / "targets" / "toy.yaml").write_text(textwrap.dedent(f"""\
    ---
    name: "toy"
    lscpu_flags: ["xla", "{flag}"]
    ctypes: ["float32"]
    ...
    """))
    (root / "primitives" / "toy.yaml").write_text(textwrap.dedent("""\
    ---
    primitive_name: "toy_add"
    group: "toy"
    parameters:
      - {name: "a", ctype: "register"}
      - {name: "b", ctype: "register"}
    returns: {ctype: "register"}
    definitions:
      - target_extension: "toy"
        ctype: ["float32"]
        lscpu_flags: ["xla"]
        implementation: |
          return a + b
    testing:
      - name: "adds"
        requires: []
        implementation: |
          a = ctx.array((2, 4), ctype)
          b = ctx.array((2, 4), ctype)
          ctx.allclose(ops.toy_add(a, b),
                       np.asarray(a, np.float64) + np.asarray(b, np.float64), ctype)
    ...
    """))


def test_fingerprint_change_forces_regeneration(tmp_path):
    upd = tmp_path / "upd"
    _upd(upd)
    cfg = GenConfig(target="toy", upd_paths=(str(upd),))
    dir1, res1 = generate_library(cfg, tmp_path / "cache")
    assert res1 is not None                              # cold: pipeline ran
    dir1b, res1b = generate_library(cfg, tmp_path / "cache")
    assert dir1b == dir1 and res1b is None               # warm: cache hit
    # editing any UPD document changes the fingerprint -> new artifact
    _upd(upd, flag="v2")
    dir2, res2 = generate_library(cfg, tmp_path / "cache")
    assert res2 is not None
    assert dir2 != dir1


def test_hardware_flag_change_forces_regeneration(tmp_path):
    upd = tmp_path / "upd"
    _upd(upd)
    base = dict(upd_paths=(str(upd),))
    d1, r1 = generate_library(
        GenConfig(target="toy", hardware_flags=("xla",), **base),
        tmp_path / "cache")
    d2, r2 = generate_library(
        GenConfig(target="toy", hardware_flags=("xla", "v1"), **base),
        tmp_path / "cache")
    assert r1 is not None and r2 is not None
    assert d1 != d2                                      # hardware keys the artifact
    # identical probe -> hit
    d3, r3 = generate_library(
        GenConfig(target="toy", hardware_flags=("xla",), **base),
        tmp_path / "cache")
    assert d3 == d1 and r3 is None


def test_generator_version_bump_forces_regeneration(tmp_path, monkeypatch):
    upd = tmp_path / "upd"
    _upd(upd)
    cfg = GenConfig(target="toy", upd_paths=(str(upd),))
    d1, _ = generate_library(cfg, tmp_path / "cache")
    from repro.core import cache as cache_mod

    monkeypatch.setattr(cache_mod, "GENERATOR_VERSION", "999.0.0-test")
    d2, r2 = generate_library(cfg, tmp_path / "cache")
    assert r2 is not None                                # bump retired the artifact
    assert d2 != d1


def test_cache_key_and_index_recorded(tmp_path):
    upd = tmp_path / "upd"
    _upd(upd)
    cfg = GenConfig(target="toy", upd_paths=(str(upd),))
    pkg_dir, _ = generate_library(cfg, tmp_path / "cache")
    import json

    key = json.loads((pkg_dir / "_cache_key.json").read_text())
    assert key["target"] == "toy"
    assert key["hardware_flags"] == ["v1", "xla"]        # sorted probe flags
    assert key["generator_version"]
    from repro.core import ArtifactCache

    stats = ArtifactCache(tmp_path / "cache").stats()
    assert pkg_dir.name in stats["index"]
    assert stats["index"][pkg_dir.name]["digest"] == key["digest"]


def test_bench_winner_store_is_hardware_keyed(tmp_path):
    """Bench winners share the package's content address minus the variant:
    same corpus + target on different hardware -> different bench entries."""
    from repro.core.cache import ArtifactCache, CacheKey

    store = ArtifactCache(tmp_path)
    k1 = CacheKey("fp", "cpu_xla", ("xla",), "2.0.0", "deadbeef")
    k2 = CacheKey("fp", "cpu_xla", ("avx512", "xla"), "2.0.0", "deadbeef")
    assert store.bench_path(k1) != store.bench_path(k2)
    # ...but variant-independent: all package flavours share one winner file
    k3 = CacheKey("fp", "cpu_xla", ("xla",), "2.0.0", "cafecafe")
    assert store.bench_path(k1) == store.bench_path(k3)
    store.bench_store(k1, {"p/float32": {"winner": 1}})
    assert store.bench_load(k3) == {"p/float32": {"winner": 1}}
    assert store.bench_load(k2) == {}


def test_bench_selection_persists_winners(tmp_path):
    """Regression: on targets where primitives have ≥2 valid candidates the
    measured winners must land in the unified bench store (a bad key once
    crashed bench_store after the first real benchmark)."""
    import json

    lib = load_library("pallas_interpret", only=("hadd",),
                       use_bench_selection=True, build_root=tmp_path)
    assert "hadd" in lib.PRIMITIVES
    benches = list((tmp_path / "bench").glob("pallas_interpret_*.json"))
    assert len(benches) == 1
    data = json.loads(benches[0].read_text())
    assert "hadd/float32" in data
    assert "winner" in data["hadd/float32"]
    assert len(data["hadd/float32"]["times_us"]) >= 2
    # second generation of a different variant reuses the same winner file
    _, res2 = generate_library(
        GenConfig(target="pallas_interpret", only=("hadd",),
                  use_bench_selection=True, emit_docs=True),
        tmp_path)
    assert res2 is not None
    assert list((tmp_path / "bench").glob("*.json")) == benches


def test_cli_generate_and_cache_roundtrip(tmp_path, capsys):
    from repro.core.cli import main

    upd = tmp_path / "upd"
    _upd(upd)
    rc = main(["generate", "--targets", "toy", "--upd-path", str(upd),
               "--build-root", str(tmp_path / "cache")])
    assert rc == 0
    out = capsys.readouterr().out
    assert "toy:" in out
    rc = main(["cache", "stats", "--build-root", str(tmp_path / "cache")])
    assert rc == 0
    assert "toy" in capsys.readouterr().out
    rc = main(["cache", "clear", "--build-root", str(tmp_path / "cache")])
    assert rc == 0
    assert "removed" in capsys.readouterr().out


def test_cache_gc_age_eviction(tmp_path):
    """cache gc --max-age-days: evicts only stale packages/bench entries,
    prunes the index to match, and leaves stats/clear semantics intact."""
    import os
    import time

    from repro.core import ArtifactCache

    upd = tmp_path / "upd"
    _upd(upd)
    cfg = GenConfig(target="toy", upd_paths=(str(upd),))
    pkg_dir, _ = generate_library(cfg, tmp_path / "cache")
    store = ArtifactCache(tmp_path / "cache")
    from repro.core.cache import CacheKey

    fresh_key = CacheKey("fp", "toy", ("xla",), "2.0.0")
    store.bench_store(fresh_key, {"p/float32": {"winner": 0}})
    stale_bench = store.bench_root / "toy_deadbeefdeadbeef.json"
    stale_bench.write_text("{}")
    old = time.time() - 10 * 86400
    os.utime(pkg_dir / "_cache_key.json", (old, old))
    os.utime(stale_bench, (old, old))

    # nothing is young enough to die at 30 days
    assert store.gc(30) == 0
    # at 5 days the aged package and aged bench entry go, the fresh one stays
    assert store.gc(5) == 2
    assert not pkg_dir.exists()
    assert store.bench_path(fresh_key).exists()
    stats = store.stats()
    assert pkg_dir.name not in stats["index"]
    assert pkg_dir.name not in stats["packages"]

    # regeneration after gc is a clean cold start
    pkg_dir2, res2 = generate_library(cfg, tmp_path / "cache")
    assert res2 is not None and pkg_dir2.exists()


def test_cli_cache_gc(tmp_path, capsys):
    from repro.core.cli import main

    upd = tmp_path / "upd"
    _upd(upd)
    assert main(["generate", "--targets", "toy", "--upd-path", str(upd),
                 "--build-root", str(tmp_path / "cache")]) == 0
    capsys.readouterr()
    # gc without --max-age-days is a usage error
    assert main(["cache", "gc", "--build-root", str(tmp_path / "cache")]) == 2
    capsys.readouterr()
    rc = main(["cache", "gc", "--max-age-days", "30",
               "--build-root", str(tmp_path / "cache")])
    assert rc == 0
    assert "removed 0 expired" in capsys.readouterr().out


def test_cli_bench_sweep_persists_flash_attention_winners(tmp_path, capsys):
    """ISSUE 3 acceptance: `python -m repro.core bench` runs end-to-end on CPU
    and persists flash_attention fwd+bwd block-size winners into the
    content-addressed cache under the probed hardware key."""
    import json

    from repro.core.cli import main

    rc = main(["bench", "--smoke", "--targets", "pallas_interpret",
               "--build-root", str(tmp_path / "cache"),
               "--report", str(tmp_path / "report.json")])
    assert rc == 0
    report = json.loads((tmp_path / "report.json").read_text())
    assert report["smoke"] is True
    tgt = report["targets"]["pallas_interpret"]
    assert tgt["hardware_flags"]                  # probed hardware key recorded
    winners = tgt["winners"]
    for key in ("flash_attention/float32", "flash_attention_bwd/float32"):
        assert key in winners, sorted(winners)
        assert len(winners[key]["times_us"]) >= 2  # ≥2 block-size candidates
    # winners live in the unified hardware-keyed bench store
    bench_file = tmp_path / "cache" / "bench" / tgt["bench_entry"]
    assert bench_file.exists()
    persisted = json.loads(bench_file.read_text())
    assert "flash_attention_bwd/float32" in persisted
    # a second sweep reuses the persisted winners (no re-measure): same file
    capsys.readouterr()
    assert main(["bench", "--smoke", "--targets", "pallas_interpret",
                 "--build-root", str(tmp_path / "cache")]) == 0
    assert json.loads(bench_file.read_text()) == persisted


def test_bench_smoke_winners_do_not_pin_real_selection(tmp_path, capsys):
    """A smoke sweep (n_iter=1) must not permanently replace real adaptive
    selection: a later full-iteration sweep re-measures stale smoke entries."""
    import json

    from repro.core.cli import main

    root = str(tmp_path / "cache")
    assert main(["bench", "--smoke", "--targets", "cpu_xla",
                 "--build-root", root,
                 "--report", str(tmp_path / "smoke.json")]) == 0
    smoke = json.loads((tmp_path / "smoke.json").read_text())
    w = smoke["targets"]["cpu_xla"]["winners"]["attention_decode/float32"]
    assert w["n_iter"] == 1
    capsys.readouterr()
    assert main(["bench", "--targets", "cpu_xla", "--build-root", root,
                 "--report", str(tmp_path / "full.json")]) == 0
    full = json.loads((tmp_path / "full.json").read_text())
    w2 = full["targets"]["cpu_xla"]["winners"]["attention_decode/float32"]
    assert w2["n_iter"] > 1                  # re-measured, not reused
    # ...and the real measurement now sticks: smoke afterwards reuses it
    capsys.readouterr()
    assert main(["bench", "--smoke", "--targets", "cpu_xla",
                 "--build-root", root,
                 "--report", str(tmp_path / "smoke2.json")]) == 0
    smoke2 = json.loads((tmp_path / "smoke2.json").read_text())
    assert smoke2["targets"]["cpu_xla"]["winners"][
        "attention_decode/float32"]["n_iter"] == w2["n_iter"]


def test_cli_bench_rejects_bad_targets(tmp_path, capsys):
    from repro.core.cli import main

    assert main(["bench", "--targets", "nope",
                 "--build-root", str(tmp_path / "cache")]) == 2
    assert main(["bench", "--targets", "pallas_tpu",   # not host-runnable
                 "--build-root", str(tmp_path / "cache")]) == 2
    capsys.readouterr()


def test_bench_diff_winner_logic():
    """Trajectory diffing: surface changes always fail; a winner flip fails
    only when the FRESH measurement shows a clear (>=1.5x) margin, so
    near-tie candidates can't flake CI."""
    from repro.core.cli import _diff_bench_winners

    def entry(winner, cands, times):
        return {"winner": winner, "candidates": cands, "times_us": times,
                "n_iter": 3}

    old = {"winners": {"p/float32": entry(0, [0, 5], [100.0, 200.0])}}

    # identical winners: clean
    assert _diff_bench_winners(old, old) == []
    # flip with clear margin in the fresh run: regression
    fresh = {"winners": {"p/float32": entry(5, [0, 5], [400.0, 100.0])}}
    (p,) = _diff_bench_winners(old, fresh)
    assert "def[0] -> def[5]" in p and "1.5x" in p
    # flip within noise: reported but NOT a failure
    close = {"winners": {"p/float32": entry(5, [0, 5], [110.0, 100.0])}}
    assert _diff_bench_winners(old, close) == []
    # candidate-set change: always a failure (corpus moved under trajectory)
    cset = {"winners": {"p/float32": entry(0, [0, 5, 9], [1.0, 2.0, 3.0])}}
    assert any("candidate set changed" in p
               for p in _diff_bench_winners(old, cset))
    # benched-surface change in either direction: failure
    assert any("not benched now" in p
               for p in _diff_bench_winners(old, {"winners": {}}))
    assert any("newly benched" in p
               for p in _diff_bench_winners({"winners": {}}, old))


def test_cli_bench_trajectory_roundtrip(tmp_path, capsys, monkeypatch):
    """`bench --report` (bare) writes BENCH_<target>.json at the repo root;
    `bench --diff` against that trajectory passes on an unchanged corpus."""
    import json

    from repro.core import cli

    monkeypatch.setattr(cli, "_repo_root", lambda: tmp_path)
    root = str(tmp_path / "cache")
    assert cli.main(["bench", "--smoke", "--targets", "cpu_xla",
                     "--build-root", root, "--report"]) == 0
    traj = tmp_path / "BENCH_cpu_xla.json"
    assert traj.exists()
    data = json.loads(traj.read_text())
    assert data["target"] == "cpu_xla" and data["winners"]
    capsys.readouterr()
    assert cli.main(["bench", "--smoke", "--targets", "cpu_xla",
                     "--build-root", root, "--diff", str(traj)]) == 0
    # trajectory for a target that wasn't swept: usage error
    capsys.readouterr()
    assert cli.main(["bench", "--smoke", "--targets", "pallas_interpret",
                     "--build-root", root, "--diff", str(traj)]) == 2
    capsys.readouterr()


def test_checked_in_bench_trajectory_matches_corpus_surface():
    """The committed BENCH_cpu_xla.json must track the live corpus: every
    benched (primitive, ctype) pair with >1 valid cpu_xla candidate appears,
    with the candidate indices the corpus declares today."""
    import json
    import pathlib

    from repro.core.cli import _repo_root
    from repro.core.corpus import load_corpus
    from repro.core.select import valid_candidates

    traj_path = _repo_root() / "BENCH_cpu_xla.json"
    assert traj_path.exists(), "run: python -m repro.core bench " \
                               "--targets cpu_xla --report"
    traj = json.loads(traj_path.read_text())
    assert traj["smoke"] is False        # trajectory is a REAL measurement
    corpus = load_corpus(())
    hw = set(traj["hardware_flags"])
    for name, prim in corpus.primitives.items():
        if prim.bench is None:
            continue
        for ctype in corpus.targets["cpu_xla"].ctypes:
            cands = valid_candidates(prim, "cpu_xla", ctype, hw)
            if len(cands) < 2:
                continue
            key = f"{name}/{ctype}"
            assert key in traj["winners"], key
            assert traj["winners"][key]["candidates"] == \
                [prim.definitions.index(c) for c in cands], key


# -- shared store root (many processes, one directory) --------------------------


def test_shared_commit_publishes_by_rename(tmp_path):
    """Shared-mode commit stages privately and publishes atomically: a second
    writer racing the same name loses the rename and adopts the winner."""
    from dataclasses import dataclass

    from repro.core.cache import ArtifactCache, CacheKey

    @dataclass
    class F:
        relpath: str
        content: str

    key = CacheKey("fp", "cpu_xla", ("avx2",), "2.0.0", "v")
    ns = key.hw_namespace()
    a = ArtifactCache(tmp_path, shared=True, namespace=ns)
    b = ArtifactCache(tmp_path, shared=True, namespace=ns)
    d1 = a.commit("pkg_x", key, [F("m.py", "WINNER = 1\n")])
    d2 = b.commit("pkg_x", key, [F("m.py", "WINNER = 2\n")])
    assert d1 == d2
    assert (d1 / "m.py").read_text() == "WINNER = 1\n"   # first publish wins
    assert a.lookup("pkg_x") is not None
    # no staging litter survives
    leftovers = [p for p in a.package_root.iterdir()
                 if p.name.startswith(".")]
    assert leftovers == []
    # namespace isolation: a different hardware class sees nothing
    other = ArtifactCache(tmp_path, shared=True, namespace="hw_other")
    assert other.lookup("pkg_x") is None


def test_shared_writer_election_and_wait(tmp_path):
    from repro.core.cache import ArtifactCache

    store = ArtifactCache(tmp_path, shared=True, namespace="hw_t")
    assert store.acquire_writer("p") is True
    assert store.acquire_writer("p") is False      # held
    store.release_writer("p")
    assert store.acquire_writer("p") is True       # released -> retaken
    # a stale lock (crashed writer) is broken and retaken
    lock = store._lock_path("q")
    store._lock_root.mkdir(parents=True, exist_ok=True)
    lock.write_text("999999")
    import os

    old = 10_000.0
    os.utime(lock, (os.stat(lock).st_atime - old,
                    os.stat(lock).st_mtime - old))
    assert store.acquire_writer("q", stale_s=600.0) is True
    # wait_for with no lock and no package returns promptly (writer failed)
    assert store.wait_for("never", timeout_s=1.0) is None


def test_shared_store_race_one_writer_one_warm_hit(tmp_path):
    """Two PROCESSES generating the same artifact key against one shared
    store root: exactly one runs the generator, the other takes the warm hit
    (zero GPOs re-run) — the fleet warm-path acceptance criterion."""
    import os
    import subprocess
    import sys
    import textwrap as tw

    worker = tmp_path / "worker.py"
    worker.write_text(tw.dedent("""
        import sys
        from repro.core import GenConfig
        from repro.core.library import generate_library

        pkg_dir, result = generate_library(
            GenConfig(target="cpu_xla", emit_tests=False, emit_build=True))
        print("GENERATED" if result is not None else "WARM")
        print(pkg_dir)
    """))
    import pathlib

    import repro.core

    src = str(pathlib.Path(repro.core.__file__).resolve().parents[2])
    env = dict(os.environ)
    env["TSL_STORE_ROOT"] = str(tmp_path / "store")
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    procs = [subprocess.Popen([sys.executable, str(worker)], env=env,
                              stdout=subprocess.PIPE, text=True)
             for _ in range(2)]
    outs = [p.communicate(timeout=600)[0].split() for p in procs]
    assert all(p.returncode == 0 for p in procs)
    marks = sorted(o[0] for o in outs)
    assert marks == ["GENERATED", "WARM"], outs
    assert outs[0][1] == outs[1][1]              # same published package dir
    # the published package lives under the hardware-key namespace
    store_root = tmp_path / "store" / "pkg"
    spaces = [d.name for d in store_root.iterdir()]
    assert len(spaces) == 1 and spaces[0].startswith("hw_")
