"""Validation/enrichment GPO (paper Fig 5 ①, first pipeline operator).

*"The very first GPO validates the input provided to the generator. While this
step may be omitted, it can be very beneficial when searching for errors
within the input and enriching the provided user data."*

Converts raw YAML docs → typed ``TargetDef``/``PrimitiveDef`` after schema
application; collects all errors before failing.

Corpus-phase GPO: validation is target-agnostic, so it runs ONCE per UPD
fingerprint (on a :class:`~.model.CorpusBuild`) no matter how many targets
are subsequently generated from the shared corpus.
"""

from __future__ import annotations

from . import schema as S
from .model import CorpusBuild, ImplDef, ParamDef, PrimitiveDef, TargetDef, TestDef


class ValidateGPO:
    name = "validate"

    def run(self, ctx: CorpusBuild) -> CorpusBuild:
        self._targets(ctx)
        self._primitives(ctx)
        self._cross_check(ctx)
        return ctx

    # -- targets ------------------------------------------------------------

    def _targets(self, ctx: CorpusBuild) -> None:
        for raw in ctx.raw_targets:
            raw = {k: v for k, v in raw.items() if not k.startswith("__")}
            doc, errs, warns = S.TARGET_SCHEMA.apply(raw)
            ctx.errors += errs
            ctx.warnings += [w for w in warns if ".__" not in w]
            if errs:
                continue
            known = S.TARGET_SCHEMA.entry_names()
            extra = {k: v for k, v in doc.items() if k not in known}
            t = TargetDef(
                name=doc["name"],
                vendor=doc["vendor"],
                flags=tuple(doc["lscpu_flags"]),
                ctypes=tuple(doc["ctypes"]),
                default_ctype=doc["default_ctype"],
                lanes=doc["lanes"],
                sublanes=doc["sublanes"],
                mxu=tuple(doc["mxu"]),
                vmem_bytes=doc["vmem_bytes"],
                hbm_bytes=doc["hbm_bytes"],
                peak_flops_bf16=float(doc["peak_flops_bf16"]),
                hbm_bw=float(doc["hbm_bw"]),
                ici_bw=float(doc["ici_bw"]),
                ici_links=doc["ici_links"],
                interpret=doc["interpret"],
                runs_on_host=doc["runs_on_host"],
                dtype_map=doc["dtype_map"],
                description=doc["description"],
                extra=extra,
            )
            if t.name in ctx.targets:
                ctx.fail(f"duplicate target {t.name!r}")
            ctx.targets[t.name] = t

    # -- primitives ----------------------------------------------------------

    def _primitives(self, ctx: CorpusBuild) -> None:
        for raw in ctx.raw_primitives:
            raw = {k: v for k, v in raw.items() if not k.startswith("__")}
            doc, errs, warns = S.PRIMITIVE_SCHEMA.apply(raw)
            ctx.errors += errs
            if errs:
                continue
            params = tuple(
                ParamDef(
                    name=p["name"],
                    ctype=p["ctype"],
                    default=(None if p["default"] is None else repr(p["default"])
                             if not isinstance(p["default"], str) else p["default"]),
                    attributes=tuple(p["attributes"]),
                    description=p["description"],
                )
                for p in doc["parameters"]
            )
            defs_list: list[ImplDef] = []
            for d in doc["definitions"]:
                tgts = d["target_extension"]
                if isinstance(tgts, str):
                    tgts = [tgts]
                if not (isinstance(tgts, list) and all(isinstance(t, str) for t in tgts)):
                    ctx.fail(
                        f"primitive {doc['primitive_name']!r}: target_extension must "
                        f"be str or list[str], got {tgts!r}"
                    )
                    continue
                for tgt_name in tgts:
                    defs_list.append(ImplDef(
                        target_extension=tgt_name,
                        ctypes=tuple(d["ctype"]),
                        flags=tuple(d["lscpu_flags"]),
                        implementation=d["implementation"],
                        is_native=d["is_native"],
                        helpers=d["helpers"],
                        cost={k: str(v) for k, v in d["cost"].items()},
                        note=d["note"],
                        lint=d["lint"],
                    ))
            defs = tuple(defs_list)
            tests = tuple(
                TestDef(
                    name=t["name"],
                    implementation=t["implementation"],
                    requires=tuple(t["requires"]),
                )
                for t in doc["testing"]
            )
            known = S.PRIMITIVE_SCHEMA.entry_names()
            extra = {k: v for k, v in doc.items() if k not in known}
            prim = PrimitiveDef(
                name=doc["primitive_name"],
                group=doc["group"],
                brief=doc["brief"],
                parameters=params,
                returns_ctype=doc["returns"]["ctype"],
                definitions=defs,
                tests=tests,
                dispatch=doc["dispatch"],
                bench=doc["bench"],
                cost_shapes=tuple(doc["cost_shapes"]),
                lint=doc["lint"],
                extra=extra,
            )
            if prim.name in ctx.primitives:
                ctx.fail(f"duplicate primitive {prim.name!r}")
            ctx.primitives[prim.name] = prim

    # -- cross checks ---------------------------------------------------------

    def _cross_check(self, ctx: CorpusBuild) -> None:
        for prim in ctx.primitives.values():
            for d in prim.definitions:
                if d.target_extension not in ctx.targets:
                    ctx.fail(
                        f"primitive {prim.name!r}: definition references unknown "
                        f"target {d.target_extension!r}"
                    )
                    continue
                tgt = ctx.targets[d.target_extension]
                for ct in d.ctypes:
                    if ct not in tgt.ctypes:
                        ctx.warn(
                            f"primitive {prim.name!r}: ctype {ct!r} not listed for "
                            f"target {d.target_extension!r}"
                        )
            if not prim.tests:
                # paper §4.1: "If no test cases are defined, a warning will be emitted."
                ctx.warn(f"primitive {prim.name!r}: no test cases defined")
        # NOTE: existence of the *requested* generation target is a target-phase
        # concern now (SelectGPO fails on unknown targets); the corpus itself
        # is target-agnostic.
