"""Pallas TPU kernels: blockwise flash attention (fwd + bwd) with GQA folding.

TPU adaptation of the (GPU-origin) FlashAttention online-softmax algorithm
(DESIGN.md §2): instead of warp-level shared-memory staging, blocks of
Q (bq × D) and K/V (bk × D) are staged HBM→VMEM by the Pallas pipeline; the
two matmuls per step are MXU-shaped (bq,D)x(D,bk) and (bq,bk)x(bk,D) with
f32 VREG accumulators held in VMEM scratch across the sequential k-grid.

Forward grid: (B, H, Sq/bq, Sk/bk) — the last dimension is "arbitrary"
(sequential) so the running (m, l, acc) scratch carries across k blocks; the
first three are "parallel". GQA is folded via the K/V index maps
(h -> h // group), so KV blocks are fetched once per KV head group without
materializing the H-times-replicated cache in HBM — that replication is
exactly the waste the GPU implementations avoid with shared memory, adapted
here to VMEM reuse.

Backward (FlashAttention-2 style recomputation): the forward additionally
emits per-row logsumexp residuals ``lse = m + log(l)`` of shape (B, H, Sq),
so the backward never re-materializes the (Sq, Sk) score matrix — each tile
is recomputed as ``p = exp(s - lse)`` and immediately contracted away:

* ``dq`` kernel, q-tiled: grid (B, H, Sq/bq, Sk/bk), sequential over k
  blocks, accumulating ``dq += (p * (dO·vᵀ - delta)) @ k`` in VMEM scratch;
* ``dk/dv`` kernel, k-tiled: grid (B, H, Sk/bk, Sq/bq), sequential over q
  blocks, accumulating ``dv += pᵀ @ dO`` and ``dk += dsᵀ @ q`` per *query*
  head (f32 outputs); the GQA head-group reduction to KV heads is a cheap
  O(Sk·D) reshape-sum done by the caller.

``delta = rowsum(dO ⊙ O)`` is O(Sq) per head and precomputed outside.

VMEM per step (bq=bk=512, D=128, bf16): q 128K, k/v 256K, acc f32 256K,
p f32 1M — ≈ 2 MiB, far under the v5e budget; larger bq trades grid steps
for VMEM (hillclimb lever recorded in EXPERIMENTS.md §Perf).

Causal masking uses global row/col iota comparison; fully-masked (qi, ki)
tiles still execute (static grid) — skipping them is the classic 2x win,
implemented as an early-exit `when` on the block predicate shared by the
forward and both backward kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


NEG_INF = -1e30
_LANES = 128


def _block_needed(qi, ki, *, causal: bool, q_offset: int, bq: int, bk: int):
    """Static-grid early-exit predicate: is causal tile (qi, ki) reachable?"""
    return jnp.logical_or(
        jnp.logical_not(causal),
        (ki * bk) <= (qi * bq + bq - 1 + q_offset),
    )


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *refs, scale: float,
                  causal: bool, kv_len: int, q_offset: int,
                  bq: int, bk: int):
    # refs = (m, l, acc) scratch, optionally preceded by an lse output ref
    if len(refs) == 4:
        lse_ref, m_scr, l_scr, acc_scr = refs
    else:
        lse_ref = None
        m_scr, l_scr, acc_scr = refs
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # global positions of this tile (ends-aligned causal: logical q row r
    # attends to keys <= r + q_offset, supporting prefill continuation;
    # q_offset = kv_len - logical_sq, computed on the UNPADDED q length)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level early exit: skip fully-masked causal tiles
    block_needed = _block_needed(qi, ki, causal=causal, q_offset=q_offset,
                                 bq=bq, bk=bk)

    @pl.when(block_needed)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]                         # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_new = corr * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[:, :1]
        o = acc_scr[...] / jnp.maximum(l, 1e-30)
        o = jnp.where(l > 0.0, o, 0.0)
        o_ref[0, 0] = o.astype(o_ref.dtype)
        if lse_ref is not None:
            m = m_scr[:, 0]
            lv = l_scr[:, 0]
            # fully-masked rows: lse := 0 keeps the backward's
            # exp(NEG_INF - lse) at exactly 0 instead of NaN
            lse_ref[0, 0] = jnp.where(lv > 0.0, m + jnp.log(jnp.maximum(lv, 1e-30)),
                                      0.0)


def _prep(q, k, block_q, block_k, scale, kv_len, q_offset):
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    assert h % kh == 0, (h, kh)
    group = h // kh
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kv_len = kv_len if kv_len is not None else sk
    q_offset = q_offset if q_offset is not None else kv_len - sq
    return b, h, kh, sq, sk, d, group, bq, bk, scale, kv_len, q_offset


def _fa_call(q, k, v, *, causal, scale, kv_len, q_offset, block_q, block_k,
             interpret, emit_lse: bool):
    b, h, kh, sq, sk, d, group, bq, bk, scale, kv_len, q_offset = _prep(
        q, k, block_q, block_k, scale, kv_len, q_offset)
    grid = (b, h, sq // bq, sk // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, kv_len=kv_len,
        q_offset=q_offset, bq=bq, bk=bk)
    out_shape = jax.ShapeDtypeStruct(q.shape, q.dtype)
    out_spec = pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0))
    if emit_lse:
        out_shape = [out_shape, jax.ShapeDtypeStruct((b, h, sq), jnp.float32)]
        out_spec = [out_spec,
                    pl.BlockSpec((1, 1, bq), lambda b_, h_, qi, ki: (b_, h_, qi))]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),   # running max (lane-replicated)
            pltpu.VMEM((bq, _LANES), jnp.float32),   # running denominator
            pltpu.VMEM((bq, d), jnp.float32),        # output accumulator
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="tsl_flash_attention_fwd" if emit_lse else "tsl_flash_attention",
    )(q, k, v)


def flash_attention_4d(q, k, v, *, causal: bool = True, scale: float | None = None,
                       kv_len: int | None = None, q_offset: int | None = None,
                       block_q: int = 512, block_k: int = 512,
                       interpret: bool = False):
    """q: (B,H,Sq,D); k,v: (B,KH,Sk,D). Shapes pre-padded to block multiples.

    ``q_offset``: causal alignment of logical q row 0 (defaults kv_len - sq)."""
    return _fa_call(q, k, v, causal=causal, scale=scale, kv_len=kv_len,
                    q_offset=q_offset, block_q=block_q, block_k=block_k,
                    interpret=interpret, emit_lse=False)


def flash_attention_fwd_4d(q, k, v, *, causal: bool = True,
                           scale: float | None = None, kv_len: int | None = None,
                           q_offset: int | None = None, block_q: int = 512,
                           block_k: int = 512, interpret: bool = False):
    """Forward that also returns the (B, H, Sq) f32 logsumexp residual — the
    only extra state the recomputation backward needs (O(Sq), not O(Sq·Sk))."""
    return _fa_call(q, k, v, causal=causal, scale=scale, kv_len=kv_len,
                    q_offset=q_offset, block_q=block_q, block_k=block_k,
                    interpret=interpret, emit_lse=True)


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, dq_scr, *, scale: float, causal: bool,
                         kv_len: int, q_offset: int, bq: int, bk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    @pl.when(_block_needed(qi, ki, causal=causal, q_offset=q_offset, bq=bq, bk=bk))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, D)
        do = do_ref[0, 0].astype(jnp.float32)         # (bq, D)
        lse = lse_ref[0, 0][:, None]                  # (bq, 1)
        delta = delta_ref[0, 0][:, None]              # (bq, 1)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse)                          # (bq, bk), masked -> 0
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # (bq, bk)
        ds = p * (dp - delta)
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        dq_ref[0, 0] = (dq_scr[...] * scale).astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_scr, dv_scr, *, scale: float,
                          causal: bool, kv_len: int, q_offset: int,
                          bq: int, bk: int):
    ki = pl.program_id(2)
    qi = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    @pl.when(_block_needed(qi, ki, causal=causal, q_offset=q_offset, bq=bq, bk=bk))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, D)
        do = do_ref[0, 0].astype(jnp.float32)         # (bq, D)
        lse = lse_ref[0, 0][:, None]                  # (bq, 1)
        delta = delta_ref[0, 0][:, None]              # (bq, 1)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse)                          # (bq, bk), masked -> 0
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # (bk, D)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # (bq, bk)
        ds = p * (dp - delta)
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # (bk, D)

    @pl.when(qi == nq - 1)
    def _finalize():
        dk_ref[0, 0] = (dk_scr[...] * scale).astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd_dq_4d(q, k, v, do, lse, delta, *, causal: bool = True,
                              scale: float | None = None,
                              kv_len: int | None = None,
                              q_offset: int | None = None, block_q: int = 512,
                              block_k: int = 512, interpret: bool = False):
    """dq, q-tiled: grid (B, H, Sq/bq, Sk/bk), sequential k accumulation.

    ``lse``/``delta``: (B, H, Sq) f32 residuals. Shapes pre-padded."""
    b, h, kh, sq, sk, d, group, bq, bk, scale, kv_len, q_offset = _prep(
        q, k, block_q, block_k, scale, kv_len, q_offset)
    grid = (b, h, sq // bq, sk // bk)
    kernel = functools.partial(
        _flash_bwd_dq_kernel, scale=scale, causal=causal, kv_len=kv_len,
        q_offset=q_offset, bq=bq, bk=bk)
    q_spec = pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, bk, d),
                           lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0))
    row_spec = pl.BlockSpec((1, 1, bq), lambda b_, h_, qi, ki: (b_, h_, qi))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="tsl_flash_attention_bwd_dq",
    )(q, k, v, do, lse, delta)


def flash_attention_bwd_dkv_4d(q, k, v, do, lse, delta, *, causal: bool = True,
                               scale: float | None = None,
                               kv_len: int | None = None,
                               q_offset: int | None = None, block_q: int = 512,
                               block_k: int = 512, interpret: bool = False):
    """dk/dv, k-tiled: grid (B, H, Sk/bk, Sq/bq), sequential q accumulation.

    Returns f32 (B, H, Sk, D) gradients per *query* head; the caller reduces
    head groups to KV heads (GQA) and casts — keeping the in-kernel
    accumulation and the cross-head sum in f32."""
    b, h, kh, sq, sk, d, group, bq, bk, scale, kv_len, q_offset = _prep(
        q, k, block_q, block_k, scale, kv_len, q_offset)
    grid = (b, h, sk // bk, sq // bq)
    kernel = functools.partial(
        _flash_bwd_dkv_kernel, scale=scale, causal=causal, kv_len=kv_len,
        q_offset=q_offset, bq=bq, bk=bk)
    q_spec = pl.BlockSpec((1, 1, bq, d), lambda b_, h_, ki, qi: (b_, h_, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, bk, d),
                           lambda b_, h_, ki, qi, g=group: (b_, h_ // g, ki, 0))
    row_spec = pl.BlockSpec((1, 1, bq), lambda b_, h_, ki, qi: (b_, h_, qi))
    dkv_spec = pl.BlockSpec((1, 1, bk, d), lambda b_, h_, ki, qi: (b_, h_, ki, 0))
    dkv_shape = jax.ShapeDtypeStruct((b, h, sk, d), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=[dkv_spec, dkv_spec],
        out_shape=[dkv_shape, dkv_shape],
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="tsl_flash_attention_bwd_dkv",
    )(q, k, v, do, lse, delta)
