"""zamba2-7b [hybrid]: 81L Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000 ssm_state=64
[arXiv:2411.15242; unverified]

Layout note (DESIGN.md §4): the published model interleaves two shared
attention blocks; we model ONE shared attention block applied every 6th Mamba2
layer (13 applications over 81 layers) — same parameter sharing structure,
same asymptotics. d_inner = 2·d_model = 7168, P=64 ⇒ 112 SSD heads.
"""

from .arch import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    head_dim=112,
    ssm_state=64,
    ssm_head_dim=64,
    d_inner_mult=2,
    attn_every=6,
    conv_width=4,
    rope_theta=1e4,
    source="arXiv:2411.15242; unverified",
)
