"""Fused paged attention tests (ISSUE 9).

The tentpole equivalence pin: with ``PagedConfig(fused=True)`` the engine
decodes and verifies KV-family slots DIRECTLY against the page pool through
the block table (``attention_decode_paged`` / ``attention_verify_paged``) —
and must emit exactly the tokens of the PR 8 lane-activated fallback
(``fused=False``), greedy AND sampled, through mid-stream slot reuse,
copy-on-write shared prefix pages, and speculative verify/commit. Plus the
host-spill tier: cold unshared pages evicted to host arrays under a tight
page budget must rehydrate bit-exactly (token-for-token vs the contiguous
engine, with at least one spill/rehydrate cycle observed), int8 fused pools
honour the absmax/254 bound, and the recurrent family with no paged leaves
(rwkv) falls back to lanes automatically.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist.compression import dequantize_absmax_int8
from repro.serve import (PagedConfig, PagedKVStore, Request, SamplingConfig,
                         ServeEngine)
from repro.serve.spec import SpeculationConfig


def _requests(cfg, gen_lens, prompt_len=8, seed=0, stagger=0.05, prefix=None,
              enc_len=None):
    rng = np.random.default_rng(seed)
    out = []
    for i, g in enumerate(gen_lens):
        toks = rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)
        if prefix is not None:
            toks[:len(prefix)] = prefix
        r = Request(rid=f"r{i}", tokens=toks, gen_len=g, arrival_s=i * stagger,
                    shared_prefix_len=len(prefix) if prefix is not None
                    else None)
        if cfg.family == "vlm":
            r.embeds = np.ones((cfg.vision_prefix, cfg.d_model), np.float32)
        if cfg.family == "audio":
            r.embeds = np.ones((enc_len, cfg.d_model), np.float32)
        out.append(r)
    return out


def _run(cfg, reqs, *, fused, enc_len=None, max_len=24, seed=0,
         sampling=None, speculation=None, **pkw):
    jax.clear_caches()
    eng = ServeEngine(cfg, batch=2, max_len=max_len, seed=seed,
                      enc_len=enc_len, sampling=sampling,
                      speculation=speculation,
                      paged=PagedConfig(fused=fused, **pkw))
    return eng.run([Request(**vars(r)) for r in reqs])


@pytest.mark.parametrize("arch,enc_len", [("qwen1.5-0.5b", None),
                                          ("zamba2-7b", None),
                                          ("whisper-tiny", 8),
                                          ("internvl2-2b", None)])
def test_fused_matches_lane_all_kv_families(arch, enc_len):
    """4 staggered requests on 2 lanes, greedy: the fused engine (tails-only
    activation, decode through the block table, mid-stream slot reuse) emits
    exactly the lane-activated engine's tokens — and never gathers pages
    into a lane (lane_activations == 0, tail restores observed)."""
    cfg = get_config(arch).reduced()
    max_len = 24 + (cfg.vision_prefix if cfg.family == "vlm" else 0)
    reqs = _requests(cfg, [5, 4, 4, 3], enc_len=enc_len)
    want = _run(cfg, reqs, fused=False, enc_len=enc_len, max_len=max_len)
    got = _run(cfg, reqs, fused=True, enc_len=enc_len, max_len=max_len)
    assert got["outputs"] == want["outputs"]
    assert got["paged"]["fused"] and not want["paged"]["fused"]
    assert got["paged"]["lane_activations"] == 0
    assert got["paged"]["tail_restores"] > 0       # park -> reactivate ran
    assert got["paged"]["gather_bytes_eliminated"] > 0
    assert got["paged"]["resident_requests_peak"] > 2


def test_fused_sampled_matches_lane():
    """Sampled decoding (temperature + top-k) draws the SAME per-step keys:
    the fused pool read must not change a single draw vs lane activation."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    samp = SamplingConfig(temperature=0.8, top_k=16)
    reqs = _requests(cfg, [6, 5], seed=2)
    want = _run(cfg, reqs, fused=False, sampling=samp, prefix_sharing=False)
    got = _run(cfg, reqs, fused=True, sampling=samp, prefix_sharing=False)
    assert got["outputs"] == want["outputs"]


def test_fused_cow_shared_prefix_pages():
    """Prefix sharing under fused decode: sharers read the published pages
    through their block tables (CoW keeps them immutable) and still emit the
    lane engine's tokens, prefill-once preserved."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    rng = np.random.default_rng(7)
    system = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    reqs = _requests(cfg, [3, 3, 3, 3], prompt_len=24, seed=8, prefix=system)
    want = _run(cfg, reqs, fused=False, max_len=48, page_size=16)
    got = _run(cfg, reqs, fused=True, max_len=48, page_size=16)
    assert got["outputs"] == want["outputs"]
    assert got["paged"]["prefix_hits"] == 3
    assert got["paged"]["prefix_misses"] == 1


def test_fused_speculative_matches_lane():
    """Draft/verify through attention_verify_paged (and the zamba commit
    replay through the fused span) emits exactly the lane engine's tokens."""
    for arch in ("qwen1.5-0.5b", "zamba2-7b"):
        cfg = get_config(arch).reduced()
        reqs = _requests(cfg, [8, 8, 6], seed=3)
        spec = SpeculationConfig(drafter="ngram", k_max=3, fixed_k=2)
        want = _run(cfg, reqs, fused=False, max_len=32, speculation=spec)
        got = _run(cfg, reqs, fused=True, max_len=32, speculation=spec)
        assert got["outputs"] == want["outputs"], arch
        assert got["spec"]["verify_steps"] > 0


def test_spill_rehydrate_exact_tokens():
    """A page budget too small for five resident requests forces the spill
    tier: cold parked pages move to host arrays and rehydrate on
    reactivation — tokens must still match the contiguous engine exactly,
    with at least one full spill/rehydrate cycle observed."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    reqs = _requests(cfg, [4, 4, 4, 4, 4], stagger=0.02, seed=9)
    jax.clear_caches()
    want = ServeEngine(cfg, batch=2, max_len=24, seed=0).run(
        [Request(**vars(r)) for r in reqs])
    probe = PagedConfig(page_size=8)
    jax.clear_caches()
    pb = ServeEngine(cfg, batch=2, max_len=24, seed=0,
                     paged=probe)._store.page_bytes
    got = _run(cfg, reqs, fused=True, page_size=8, hbm_budget_bytes=5 * pb)
    assert got["outputs"] == want["outputs"]
    assert got["paged"]["spills"] >= 1
    assert got["paged"]["rehydrates"] >= 1
    assert got["paged"]["host_spill_bytes"] == 0   # everything came back


def test_rwkv_falls_back_to_lanes():
    """No paged leaves -> no fused contract: the engine must flag
    fused=False and keep emitting the contiguous engine's tokens through
    the lane path."""
    cfg = get_config("rwkv6-7b").reduced()
    reqs = _requests(cfg, [5, 4, 4, 3])
    jax.clear_caches()
    want = ServeEngine(cfg, batch=2, max_len=24, seed=0).run(
        [Request(**vars(r)) for r in reqs])
    got = _run(cfg, reqs, fused=True)
    assert got["outputs"] == want["outputs"]
    assert not got["paged"]["fused"]
    assert got["paged"]["lane_activations"] > 0


def test_int8_fused_pool_absmax_bound():
    """int8 fused pools: rows written through store_donor guarantee
    |dequantized - original| <= absmax(row)/254 per last-axis row — the
    same bound the lane-path store pins, now on the (KH, NP, page, D)
    fused pool layout with its per-row scale pools."""
    rng = np.random.default_rng(3)
    shapes = {"k": jax.ShapeDtypeStruct((1, 2, 32, 8), np.float32)}
    st = PagedKVStore(shapes, {"k": 2}, page_size=8, n_pages=8, int8=True,
                      fused=True)
    donor = {"k": np.asarray(rng.normal(size=(1, 2, 32, 8)), np.float32)}
    st.attach("a", prompt_rows=32)
    st.store_donor("a", {n: jax.numpy.asarray(v) for n, v in donor.items()},
                   fill=32)
    pools = st.device_pools()
    tab = st.table_row("a", 4)
    # pool (1, KH, NP, page, D): token axis split in place at ax=2 — walk
    # the request's table to get its rows back in donor order
    kq = np.asarray(pools["k"])[0][:, tab[:4]].reshape(2, 32, 8)
    ks = np.asarray(pools["k__scale"])[0][:, tab[:4]].reshape(2, 32, 1)
    deq = np.asarray(dequantize_absmax_int8(kq, ks, dtype=np.float32))
    want = donor["k"][0]                           # (KH, 32, 8)
    err = np.abs(deq - want)
    bound = np.abs(want).max(-1, keepdims=True) / 254.0 + 1e-7
    assert (err <= bound).all()
