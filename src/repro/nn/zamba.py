"""zamba2 hybrid LM: Mamba2 backbone + ONE shared attention block applied
every ``attn_every`` layers (weight sharing — the arch's defining trick).

Structure: n_groups = n_layers // attn_every groups of [attn_every mamba
layers + shared-attn application], plus a remainder stack. Group params are
stacked (G, k, ...) for a two-level scan; the shared attention block's weights
are closed over (NOT scanned), so XLA sees a single copy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.tsl_api import ops as tsl

from repro.nn import flags as _nn_flags


def _scan(f, init, xs, **kw):
    return jax.lax.scan(f, init, xs, unroll=_nn_flags.scan_unroll(), **kw)


from .attention import (attention_decode, attention_forward, attention_prefill_chunk,
                        attention_span_paged, attention_verify, init_attention)
from .common import apply_norm_params, dense_init, embed_init, init_norm, split_keys
from .lm import lm_head
from .mamba2 import dims as m2_dims, init_mamba2, mamba2_decode, mamba2_forward


def layout(cfg) -> tuple[int, int, int]:
    """(n_groups, group_size, remainder)."""
    k = cfg.attn_every
    g = cfg.n_layers // k
    return g, k, cfg.n_layers - g * k


def _init_mamba_block(key, cfg, dtype):
    return {
        "norm": init_norm(cfg, dtype),
        "mamba": init_mamba2(key, cfg, dtype),
    }


def init_zamba(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    g, k, rem = layout(cfg)
    ks = split_keys(key, 6)
    gkeys = jnp.stack(split_keys(ks[0], g * k)).reshape(g, k, -1)
    params = {
        "embed": embed_init(ks[1], (cfg.padded_vocab, cfg.d_model), dtype),
        "groups": jax.vmap(jax.vmap(lambda kk: _init_mamba_block(kk, cfg, dtype)))(gkeys),
        "shared_attn_norm": init_norm(cfg, dtype),
        "shared_attn": init_attention(ks[2], cfg, dtype),
        "final_norm": init_norm(cfg, dtype),
        "head": dense_init(ks[3], (cfg.d_model, cfg.padded_vocab), dtype),
    }
    if rem:
        rkeys = jnp.stack(split_keys(ks[4], rem))
        params["rest"] = jax.vmap(lambda kk: _init_mamba_block(kk, cfg, dtype))(rkeys)
    return params


def _mamba_block_fwd(bp, x, cfg):
    from repro.dist.sharding import logical_constraint
    y, (h, conv) = mamba2_forward(bp["mamba"], apply_norm_params(cfg, bp["norm"], x), cfg)
    return logical_constraint(x + y, "batch", None, None)


def zamba_forward(params, tokens, cfg, *, remat: bool = True):
    """tokens (B,S) -> (logits, aux=0, None)."""
    x = tsl.embed_lookup(params["embed"], tokens)
    positions = jnp.arange(x.shape[1])

    def mamba_body(x, bp):
        return _mamba_block_fwd(bp, x, cfg), None

    def group_body(x, gp):
        x, _ = _scan(mamba_body, x, gp)
        h, _ = attention_forward(
            params["shared_attn"],
            apply_norm_params(cfg, params["shared_attn_norm"], x),
            cfg, causal=True, positions=positions)
        return x + h, None

    gb = jax.checkpoint(group_body, prevent_cse=False) if remat else group_body
    x, _ = _scan(gb, x, params["groups"])
    if "rest" in params:
        mb = jax.checkpoint(mamba_body, prevent_cse=False) if remat else mamba_body
        x, _ = _scan(mb, x, params["rest"])
    x = apply_norm_params(cfg, params["final_norm"], x)
    return lm_head(params, x, cfg), jnp.float32(0), None


def zamba_prefill(params, tokens, cfg, *, max_len: int):
    """Full-sequence prefill collecting SSM states, conv tails and shared-attn
    KV caches for decode continuation."""
    x = tsl.embed_lookup(params["embed"], tokens)
    positions = jnp.arange(x.shape[1])

    def mamba_body(x, bp):
        y, (h, conv) = mamba2_forward(
            bp["mamba"], apply_norm_params(cfg, bp["norm"], x), cfg)
        return x + y, (h, conv)

    def group_body(x, gp):
        x, (h_g, conv_g) = _scan(mamba_body, x, gp)
        h, (k, v) = attention_forward(
            params["shared_attn"],
            apply_norm_params(cfg, params["shared_attn_norm"], x),
            cfg, causal=True, positions=positions)
        return x + h, (h_g, conv_g, k, v)

    x, (h, conv, k, v) = _scan(group_body, x, params["groups"])
    pad = max_len - k.shape[3]
    if pad > 0:
        widths = ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))
        k, v = jnp.pad(k, widths), jnp.pad(v, widths)
    state = {"h": h, "conv": conv, "attn_k": k, "attn_v": v}
    if "rest" in params:
        x, (h_r, conv_r) = _scan(mamba_body, x, params["rest"])
        state["h_rest"] = h_r
        state["conv_rest"] = conv_r
    x = apply_norm_params(cfg, params["final_norm"], x[:, -1:])
    return lm_head(params, x, cfg)[:, 0], state


def zamba_prefill_chunk(params, state, tokens, pos, cfg, *, n_real=None,
                        attend=attention_prefill_chunk):
    """Continuation prefill of one chunk into a live hybrid decode state:
    the mamba layers carry (h, conv) forward exactly (padding rows are
    identity updates — see mamba2_forward), the shared attention block
    writes the chunk's K/V at rows [pos, pos+C) of each group's cache.
    ``pos``/``n_real`` may be (B,) per-slot vectors (ragged commit replay
    over the slot table); ``attend`` swaps the shared-attn span op (the
    verify path routes through the attention_verify primitive). Returns
    (logits (B,C,V), new state)."""
    x = tsl.embed_lookup(params["embed"], tokens)

    def mamba_body(x_c, inp):
        bp, h0, conv_prev = inp
        y, (h_f, conv_tail) = mamba2_forward(
            bp["mamba"], apply_norm_params(cfg, bp["norm"], x_c), cfg,
            h0=h0, conv_prev=conv_prev, n_real=n_real)
        return x_c + y, (h_f, conv_tail)

    def group_body(x_c, inp):
        gp, h_g, conv_g, kc, vc = inp
        x_c, (h_new, conv_new) = _scan(mamba_body, x_c, (gp, h_g, conv_g))
        a, kc, vc = attend(
            params["shared_attn"],
            apply_norm_params(cfg, params["shared_attn_norm"], x_c),
            kc, vc, pos, cfg)
        return x_c + a, (h_new, conv_new, kc, vc)

    x, (h, conv, kc, vc) = _scan(
        group_body, x,
        (params["groups"], state["h"], state["conv"],
         state["attn_k"], state["attn_v"]))
    new_state = {"h": h, "conv": conv, "attn_k": kc, "attn_v": vc}
    if "rest" in params:
        x, (h_r, conv_r) = _scan(
            mamba_body, x, (params["rest"], state["h_rest"],
                            state["conv_rest"]))
        new_state["h_rest"] = h_r
        new_state["conv_rest"] = conv_r
    x = apply_norm_params(cfg, params["final_norm"], x)
    return lm_head(params, x, cfg), new_state


def zamba_verify_step(params, state, tokens, pos, cfg):
    """Speculative-decoding verify span, PURE scoring: the SSM states cannot
    be truncated, so the incoming state is returned UNCHANGED (checkpoint)
    and the engine replays the accepted prefix through
    :func:`zamba_prefill_chunk` with per-slot ``n_real`` (verify_commit) —
    the shared-attn K/V slab writes of that replay are idempotent over what
    this scoring pass computed and then discarded. The shared attention
    routes through the attention_verify primitive. Returns
    (logits (B,SV,V), state)."""
    logits, _ = zamba_prefill_chunk(params, state, tokens, pos, cfg,
                                    attend=attention_verify)
    return logits, state


def init_zamba_state(cfg, batch: int, max_len: int, dtype):
    g, k, rem = layout(cfg)
    d_in, nh, n, p_dim = m2_dims(cfg)
    kw = cfg.conv_width
    state = {
        "h": jnp.zeros((g, k, batch, nh, p_dim, n), jnp.float32),
        "conv": jnp.zeros((g, k, batch, kw - 1, d_in), dtype),
        "attn_k": jnp.zeros((g, batch, cfg.n_kv_heads, max_len, cfg.hd), dtype),
        "attn_v": jnp.zeros((g, batch, cfg.n_kv_heads, max_len, cfg.hd), dtype),
    }
    if rem:
        state["h_rest"] = jnp.zeros((rem, batch, nh, p_dim, n), jnp.float32)
        state["conv_rest"] = jnp.zeros((rem, batch, kw - 1, d_in), dtype)
    return state


def state_batch_axes(state):
    """Slot-axis position per state leaf (serve-layer state surgery): the
    grouped SSM/conv leaves are (G, k, B, ...) — request axis at 2; the
    shared-attn caches (G, B, KH, S, hd) and the remainder stack
    (rem, B, ...) carry it at 1."""
    return {k: 2 if k in ("h", "conv") else 1 for k in state}


def state_page_axes(state):
    """Token-axis per leaf for PAGED serving: only the shared-attention KV
    caches (G, B, KH, S, hd) grow per token (axis 3). The SSM/conv leaves
    are fixed-size recurrent state — ``None`` marks them as the per-request
    TAIL the paged store snapshots whole (and shares at prefix boundaries)
    instead of paging."""
    return {k: 3 if k in ("attn_k", "attn_v") else None for k in state}


def _zamba_paged_chunk(params, state, pools, tables, tokens, pos, cfg, *,
                       span_op, n_real=None):
    """Fused-paged analogue of :func:`zamba_prefill_chunk`: the mamba layers
    carry the TAIL state (h, conv) exactly as before, while the shared
    attention block writes and reads its span straight against the page
    pools (attention_span_paged) — the per-group pool slices ride the group
    scan as xs/ys. Returns (logits, new tail state, new pools)."""
    x = tsl.embed_lookup(params["embed"], tokens)
    int8 = "attn_k__scale" in pools

    def mamba_body(x_c, inp):
        bp, h0, conv_prev = inp
        y, (h_f, conv_tail) = mamba2_forward(
            bp["mamba"], apply_norm_params(cfg, bp["norm"], x_c), cfg,
            h0=h0, conv_prev=conv_prev, n_real=n_real)
        return x_c + y, (h_f, conv_tail)

    def group_body(x_c, inp):
        if int8:
            gp, h_g, conv_g, kp, vp, ks, vs = inp
            ks, vs = ks[0], vs[0]
        else:
            gp, h_g, conv_g, kp, vp = inp
            ks = vs = None
        x_c, (h_new, conv_new) = _scan(mamba_body, x_c, (gp, h_g, conv_g))
        a, kp0, vp0, ks0, vs0 = attention_span_paged(
            params["shared_attn"],
            apply_norm_params(cfg, params["shared_attn_norm"], x_c),
            kp[0], vp[0], tables, pos, cfg, span_op,
            k_scale=ks, v_scale=vs)
        ys = (h_new, conv_new, kp0[None], vp0[None])
        if int8:
            ys += (ks0[None], vs0[None])
        return x_c + a, ys

    xs = [params["groups"], state["h"], state["conv"],
          pools["attn_k"], pools["attn_v"]]
    if int8:
        xs += [pools["attn_k__scale"], pools["attn_v__scale"]]
    x, ys = _scan(group_body, x, tuple(xs))
    new_state = {**state, "h": ys[0], "conv": ys[1]}
    new_pools = {**pools, "attn_k": ys[2], "attn_v": ys[3]}
    if int8:
        new_pools["attn_k__scale"], new_pools["attn_v__scale"] = ys[4], ys[5]
    if "rest" in params:
        x, (h_r, conv_r) = _scan(
            mamba_body, x, (params["rest"], state["h_rest"],
                            state["conv_rest"]))
        new_state["h_rest"] = h_r
        new_state["conv_rest"] = conv_r
    x = apply_norm_params(cfg, params["final_norm"], x)
    return lm_head(params, x, cfg), new_state, new_pools


def zamba_decode_step_paged(params, state, pools, tables, tokens_t, pos, cfg):
    """Fused paged decode: the mamba recurrence updates its TAIL state
    bit-identically to :func:`zamba_decode_step` (same mamba2_decode), and
    the shared attention block decodes straight off the page pools.
    Returns (logits (B,V), new tail state, new pools)."""
    x = tsl.embed_lookup(params["embed"], tokens_t)
    int8 = "attn_k__scale" in pools

    def mamba_step(x_t, inp):
        bp, h, conv = inp
        y, h, conv = mamba2_decode(bp["mamba"],
                                   apply_norm_params(cfg, bp["norm"], x_t),
                                   cfg, h, conv)
        return x_t + y, (h, conv)

    def group_step(x_t, inp):
        if int8:
            gp, h_g, conv_g, kp, vp, ks, vs = inp
            ks, vs = ks[0], vs[0]
        else:
            gp, h_g, conv_g, kp, vp = inp
            ks = vs = None
        x_t, (h_g, conv_g) = _scan(mamba_step, x_t, (gp, h_g, conv_g))
        a, kp0, vp0, ks0, vs0 = attention_span_paged(
            params["shared_attn"],
            apply_norm_params(cfg, params["shared_attn_norm"], x_t),
            kp[0], vp[0], tables, pos, cfg, tsl.attention_decode_paged,
            k_scale=ks, v_scale=vs)
        ys = (h_g, conv_g, kp0[None], vp0[None])
        if int8:
            ys += (ks0[None], vs0[None])
        return x_t + a, ys

    xs = [params["groups"], state["h"], state["conv"],
          pools["attn_k"], pools["attn_v"]]
    if int8:
        xs += [pools["attn_k__scale"], pools["attn_v__scale"]]
    x, ys = _scan(group_step, x, tuple(xs))
    new_state = {**state, "h": ys[0], "conv": ys[1]}
    new_pools = {**pools, "attn_k": ys[2], "attn_v": ys[3]}
    if int8:
        new_pools["attn_k__scale"], new_pools["attn_v__scale"] = ys[4], ys[5]
    if "rest" in params:
        x, (h_r, conv_r) = _scan(
            mamba_step, x, (params["rest"], state["h_rest"],
                            state["conv_rest"]))
        new_state["h_rest"] = h_r
        new_state["conv_rest"] = conv_r
    x = apply_norm_params(cfg, params["final_norm"], x)
    return lm_head(params, x, cfg)[:, 0], new_state, new_pools


def zamba_verify_step_paged(params, state, pools, tables, tokens, pos, cfg):
    """Fused paged verify span, PURE scoring for the tails: the incoming
    tail state comes back UNCHANGED (the engine replays the accepted prefix
    through :func:`zamba_verify_commit_paged`), while the span's K/V rows
    land in the pools — the replay's writes are idempotent over them and
    rejected rows sit beyond the committed kv_len. Returns
    (logits (B,SV,V), state, pools)."""
    logits, _, pools = _zamba_paged_chunk(
        params, state, pools, tables, tokens, pos, cfg,
        span_op=tsl.attention_verify_paged)
    return logits, state, pools


def zamba_verify_commit_paged(params, state, pools, tables, tokens, pos, cfg,
                              n_commit):
    """Commit replay on the pools: re-run the accepted prefix with per-slot
    ``n_commit`` (B,) real rows — n_commit == 0 is an exact identity for
    that slot's tails. Returns (new tail state, new pools)."""
    _, state, pools = _zamba_paged_chunk(
        params, state, pools, tables, tokens, pos, cfg,
        span_op=tsl.attention_verify_paged, n_real=n_commit)
    return state, pools


def zamba_decode_step(params, state, tokens_t, pos, cfg):
    x = tsl.embed_lookup(params["embed"], tokens_t)

    def mamba_step(x_t, inp):
        bp, h, conv = inp
        y, h, conv = mamba2_decode(bp["mamba"],
                                   apply_norm_params(cfg, bp["norm"], x_t),
                                   cfg, h, conv)
        return x_t + y, (h, conv)

    def group_step(x_t, inp):
        gp, h_g, conv_g, kc, vc = inp
        x_t, (h_g, conv_g) = _scan(mamba_step, x_t, (gp, h_g, conv_g))
        a, kc, vc = attention_decode(
            params["shared_attn"],
            apply_norm_params(cfg, params["shared_attn_norm"], x_t),
            kc, vc, pos, cfg)
        return x_t + a, (h_g, conv_g, kc, vc)

    x, (h, conv, kc, vc) = _scan(
        group_step, x,
        (params["groups"], state["h"], state["conv"],
         state["attn_k"], state["attn_v"]))
    new_state = {"h": h, "conv": conv, "attn_k": kc, "attn_v": vc}
    if "rest" in params:
        x, (h_r, conv_r) = _scan(
            mamba_step, x, (params["rest"], state["h_rest"], state["conv_rest"]))
        new_state["h_rest"] = h_r
        new_state["conv_rest"] = conv_r
    x = apply_norm_params(cfg, params["final_norm"], x)
    return lm_head(params, x, cfg)[:, 0], new_state
