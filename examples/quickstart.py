"""Quickstart: generate the TSL for this host, inspect the selection
manifest, and run the paper's range-count (Fig 8) through it.

    PYTHONPATH=src python examples/quickstart.py
"""

import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core import load_library


def main():
    # 1. generate + import the library for the live host (paper Fig 7 cmake
    #    flow: probe hardware -> run generator -> import)
    lib = load_library("auto")
    print(f"generated library: {lib.__name__}")
    print(f"target: {lib.TARGET_NAME}, {len(lib.PRIMITIVES)} primitives")

    # 2. selection provenance (paper §3.2 ②: flag-match heuristic results)
    man = json.loads((Path(lib.__file__).parent / "_manifest.json").read_text())
    for prim in ("hadd", "to_integral", "rmsnorm", "flash_attention"):
        sel = man["primitives"][prim]["float32"]
        print(f"  {prim:16s} score={sel['score']} candidates={sel['candidates']} "
              f"native={sel['is_native']} flags={sel['required_flags']}")

    # 3. the paper's range-count app (Fig 8b) against the generated API
    rng = np.random.default_rng(0)
    data = jnp.asarray(rng.uniform(0, 100_000, 1 << 20), jnp.float32)
    count = int(lib.ops.range_count(data, 5.0, 15.0))
    print(f"range_count([5,15]) over 1M uniforms -> {count} "
          f"(expect ~{int(1e6 * 10 / 100000)})")

    # 4. same app, different dialect: the Pallas-interpret library (the
    #    paper's 'emulator' path) — identical results, kernel execution
    lib2 = load_library("pallas_interpret", only=("range_count",))
    count2 = int(lib2.ops.range_count(data, 5.0, 15.0))
    assert count == count2
    print(f"pallas_interpret (slim, cherry-picked) agrees: {count2}")


if __name__ == "__main__":
    main()
