"""RWKV6 (Finch) block on TSL seq primitives: time-mix (WKV with
data-dependent decay via a LoRA on w) + channel-mix, token-shift throughout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.tsl_api import ops as tsl

from .common import dense_init, split_keys

_W_LORA = 64


def dims(cfg):
    k = cfg.rwkv_head_dim
    nh = cfg.d_model // k
    return nh, k


def init_rwkv6(key, cfg, dtype):
    d = cfg.d_model
    nh, hk = dims(cfg)
    ks = split_keys(key, 10)
    return {
        # time mix
        "mu_r": jnp.full((d,), 0.5, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "wr": dense_init(ks[0], (d, d), dtype),
        "wk": dense_init(ks[1], (d, d), dtype),
        "wv": dense_init(ks[2], (d, d), dtype),
        "wg": dense_init(ks[3], (d, d), dtype),
        "w_lora_a": dense_init(ks[4], (d, _W_LORA), dtype),
        "w_lora_b": dense_init(ks[5], (_W_LORA, d), dtype, scale=0.01),
        "w_base": jnp.full((d,), -6.0, jnp.float32),   # decay bias (w≈exp(-exp(-6)))
        "u_bonus": dense_init(ks[6], (nh, hk), dtype),
        "wo": dense_init(ks[7], (d, d), dtype),
        "ln_x_w": jnp.ones((d,), dtype),
        # channel mix
        "cm_mu_k": jnp.full((d,), 0.5, dtype),
        "cm_mu_r": jnp.full((d,), 0.5, dtype),
        "cm_wk": dense_init(ks[8], (d, cfg.d_ff), dtype),
        "cm_wv": dense_init(ks[9], (cfg.d_ff, d), dtype),
        "cm_wr": dense_init(ks[4], (d, d), dtype),
    }


def _decay(p, xw):
    """Data-dependent per-channel decay w_t in (0,1): exp(-exp(base + lora))."""
    lora = tsl.matmul(tsl.matmul(xw, p["w_lora_a"]), p["w_lora_b"])
    return jnp.exp(-jnp.exp(p["w_base"] + lora.astype(jnp.float32)))


def _last_real(x, n_real, prev):
    """x (B,T,D) -> the row at index n_real-1 (B,D); n_real may be traced and
    may be a (B,) per-sequence vector (ragged chunks over the slot table).

    The token-shift carry for the NEXT chunk must be the last REAL token's
    normed activation, not a padding row's. An ALL-padding chunk/row
    (n_real == 0) must pass the incoming carry ``prev`` through unchanged
    (zeros on a fresh start — what token_shift pads with)."""
    if n_real is None:
        return x[:, -1]
    n_real = jnp.asarray(n_real)
    if n_real.ndim:                     # (B,) per-sequence real lengths
        idx = (jnp.maximum(n_real, 1) - 1)[:, None, None]
        last = jnp.take_along_axis(x, idx, axis=1)[:, 0]
        keep = prev if prev is not None else jnp.zeros_like(last)
        return jnp.where(n_real[:, None] > 0, last, keep.astype(last.dtype))
    last = jnp.take(x, jnp.maximum(n_real, 1) - 1, axis=1)
    keep = prev if prev is not None else jnp.zeros_like(last)
    return jnp.where(n_real > 0, last, keep.astype(last.dtype))


def time_mix_forward(p, x, cfg, *, prev_tok=None, s0=None, n_real=None):
    """x (B,T,D) -> (y, (last_tok, s_final)).

    ``n_real`` (scalar or (B,) per-sequence, may be traced): positions
    >= n_real are padding — their WKV update is forced to the identity
    (decay 1, key 0) so ``s_final`` is exactly the state after the last real
    token, and ``last_tok`` is gathered at n_real-1. Pad y rows are garbage
    the caller discards (causality: they never feed a real position)."""
    bsz, t, d = x.shape
    nh, hk = dims(cfg)
    xr = tsl.token_shift(x, p["mu_r"], prev=prev_tok)
    xk = tsl.token_shift(x, p["mu_k"], prev=prev_tok)
    xv = tsl.token_shift(x, p["mu_v"], prev=prev_tok)
    xw = tsl.token_shift(x, p["mu_w"], prev=prev_tok)
    xg = tsl.token_shift(x, p["mu_g"], prev=prev_tok)
    r = tsl.matmul(xr, p["wr"]).reshape(bsz, t, nh, hk)
    k = tsl.matmul(xk, p["wk"]).reshape(bsz, t, nh, hk)
    v = tsl.matmul(xv, p["wv"]).reshape(bsz, t, nh, hk)
    w = _decay(p, xw).reshape(bsz, t, nh, hk).astype(x.dtype)
    if n_real is not None:
        nr = jnp.asarray(n_real)
        nr = nr[:, None] if nr.ndim else nr     # (B,) per-sequence or scalar
        valid = (jnp.arange(t)[None, :] < nr)[:, :, None, None]
        w = jnp.where(valid, w, jnp.ones_like(w))
        k = jnp.where(valid, k, jnp.zeros_like(k))
    g = tsl.silu(tsl.matmul(xg, p["wg"]))
    y, s_final = tsl.wkv6_scan(r, k, v, w, p["u_bonus"], s0=s0)
    y = y.reshape(bsz, t, d)
    y = tsl.rmsnorm(y, p["ln_x_w"], eps=cfg.norm_eps) * g
    return tsl.matmul(y, p["wo"]), (_last_real(x, n_real, prev_tok), s_final)


def time_mix_decode(p, x_t, cfg, prev_tok, s):
    """x_t (B,1,D); prev_tok (B,D); s (B,H,K,V) f32."""
    bsz, _, d = x_t.shape
    nh, hk = dims(cfg)
    xr = tsl.token_shift(x_t, p["mu_r"], prev=prev_tok)
    xk = tsl.token_shift(x_t, p["mu_k"], prev=prev_tok)
    xv = tsl.token_shift(x_t, p["mu_v"], prev=prev_tok)
    xw = tsl.token_shift(x_t, p["mu_w"], prev=prev_tok)
    xg = tsl.token_shift(x_t, p["mu_g"], prev=prev_tok)
    r = tsl.matmul(xr, p["wr"]).reshape(bsz, nh, hk)
    k = tsl.matmul(xk, p["wk"]).reshape(bsz, nh, hk)
    v = tsl.matmul(xv, p["wv"]).reshape(bsz, nh, hk)
    w = _decay(p, xw).reshape(bsz, nh, hk).astype(x_t.dtype)
    g = tsl.silu(tsl.matmul(xg, p["wg"]))
    yt, s = tsl.wkv6_decode(r, k, v, w, p["u_bonus"], s)
    yt = yt.reshape(bsz, 1, d)
    yt = tsl.rmsnorm(yt, p["ln_x_w"], eps=cfg.norm_eps) * g
    return tsl.matmul(yt, p["wo"]), x_t[:, -1], s


def channel_mix_forward(p, x, cfg, *, prev_tok=None, n_real=None):
    xk = tsl.token_shift(x, p["cm_mu_k"], prev=prev_tok)
    xr = tsl.token_shift(x, p["cm_mu_r"], prev=prev_tok)
    k = tsl.matmul(xk, p["cm_wk"])
    k = jnp.square(jax.nn.relu(k))
    out = tsl.sigmoid(tsl.matmul(xr, p["cm_wr"])) * tsl.matmul(k, p["cm_wv"])
    return out, _last_real(x, n_real, prev_tok)
