"""Cost-channel checker (TSL01x): the UPD ``cost:`` formulas as a verified,
falsifiable artifact.

The serving scheduler (serve/scheduler.py) prices admission with
``lib.cost(primitive, term, **shapes)`` — a bare ``eval`` of UPD-provided
strings. This analyzer makes every failure mode of that channel a *static*
finding instead of a runtime surprise:

* the formula must parse (TSL010);
* it may only use names, numeric literals and arithmetic — no calls,
  attributes, subscripts or comparisons, so the generated ``cost()`` eval can
  never execute anything but arithmetic (TSL011);
* every free symbol must be bound by the primitive's declared ``cost_shapes``
  vocabulary — the keyword set callers are expected to pass (TSL012; a
  cost-carrying primitive without the declaration gets TSL013);
* the primitives the serving scheduler prices must land a ``flops``, a
  ``bytes`` AND a ``comms`` term in the generated ``_cost.py`` of every
  target, for every candidate bench selection could pick (TSL014 — the
  ``comms`` term prices per-step collective bytes for mesh-sharded serving).
"""

from __future__ import annotations

import ast

from repro.core import select
from .findings import AnalysisReport

# primitives whose cost terms serve/scheduler.py consumes for admission;
# every servable target's generated package must price all of them
PRICED_PRIMITIVES: dict[str, tuple[str, ...]] = {
    "attention_decode": ("flops", "bytes", "comms"),
    "attention_prefill_chunk": ("flops", "bytes", "comms"),
    "attention_verify": ("flops", "bytes", "comms"),
    "ssd_scan": ("flops", "bytes", "comms"),
    "wkv6_scan": ("flops", "bytes", "comms"),
}

_ALLOWED_BINOPS = (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv,
                   ast.Mod, ast.Pow)
_ALLOWED_UNARY = (ast.USub, ast.UAdd)


def formula_symbols(expr: str) -> set[str]:
    """Free symbols of a (already parse-checked) cost formula."""
    return {n.id for n in ast.walk(ast.parse(expr, mode="eval"))
            if isinstance(n, ast.Name)}


def check_formula(expr: str) -> tuple[str | None, str]:
    """Validate one formula. Returns (code, detail) or (None, "") if clean."""
    try:
        tree = ast.parse(expr, mode="eval")
    except SyntaxError as e:
        return "TSL010", f"{expr!r}: {e.msg}"
    for node in ast.walk(tree):
        if isinstance(node, (ast.Expression, ast.Constant, ast.Name,
                             ast.Load)):
            if isinstance(node, ast.Constant) and not isinstance(
                    node.value, (int, float)):
                return "TSL011", (f"{expr!r}: literal {node.value!r} is not "
                                  "numeric")
            continue
        if isinstance(node, ast.BinOp) and isinstance(node.op, _ALLOWED_BINOPS):
            continue
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, _ALLOWED_UNARY):
            continue
        if isinstance(node, (*_ALLOWED_BINOPS, *_ALLOWED_UNARY)):
            continue
        return "TSL011", (f"{expr!r}: {type(node).__name__} is outside the "
                          "arithmetic whitelist")
    return None, ""


def _priced_term_gap(prim, target, hw: frozenset[str],
                     required: tuple[str, ...]) -> str | None:
    """Why (if at all) the required terms are NOT guaranteed to land in the
    generated ``_cost.py`` for (primitive, target).

    generate.py records the cost dict of the *selected* impl of the first
    ctype whose selection carries any cost; with a ``bench:`` block, bench
    selection may pick ANY valid candidate. The static guarantee therefore
    is: every selectable candidate carries all required terms (then whichever
    wins, the full term set lands), and at least one ctype is selectable."""
    pools: list[list] = []
    for ctype in target.ctypes:
        cands = select.valid_candidates(prim, target.name, ctype, hw)
        if not cands:
            continue
        if prim.bench is not None:
            pools.append(cands)
        else:
            chosen = select.choose(prim, target.name, ctype, hw)
            pools.append([chosen.impl] if chosen else [])
    selectable = [impl for pool in pools for impl in pool]
    if not selectable:
        return "no selectable definition at all"
    for impl in selectable:
        missing = [t for t in required if t not in impl.cost]
        if missing:
            i = prim.definitions.index(impl)
            return (f"def[{i}] is selectable but lacks terms {missing}")
    return None


def check_cost_channel(corpus) -> AnalysisReport:
    """Run the full TSL01x family over a validated corpus (CorpusBuild or
    CorpusIR — anything with typed ``targets``/``primitives`` mappings)."""
    rep = AnalysisReport()
    for name in sorted(corpus.primitives):
        prim = corpus.primitives[name]
        subject = f"primitive:{name}"
        declared = set(getattr(prim, "cost_shapes", ()) or ())
        has_cost = any(d.cost for d in prim.definitions)
        if has_cost and not declared:
            rep.add("TSL013",
                    "declare cost_shapes: [..] naming the shape keywords "
                    "these formulas expect",
                    subject=subject)
        if prim.bench is not None and not has_cost:
            rep.add("TSL015",
                    "bench: setup present but no definition carries cost "
                    "formulas",
                    subject=subject)
        for i, d in enumerate(prim.definitions):
            for term, expr in sorted(d.cost.items()):
                code, detail = check_formula(str(expr))
                if code:
                    rep.add(code, detail, subject=subject,
                            location=f"def[{i}] {term}")
                    continue
                if declared:
                    unbound = formula_symbols(str(expr)) - declared
                    if unbound:
                        rep.add("TSL012",
                                f"{expr!r}: {sorted(unbound)} not in "
                                f"cost_shapes {sorted(declared)}",
                                subject=subject,
                                location=f"def[{i}] {term}")

    # priced primitives: both terms must land for every servable target
    for pname, required in PRICED_PRIMITIVES.items():
        prim = corpus.primitives.get(pname)
        if prim is None:
            continue        # slim corpora without serving are legitimate
        for tname in sorted(corpus.targets):
            tgt = corpus.targets[tname]
            hw = frozenset(tgt.flags)
            gap = _priced_term_gap(prim, tgt, hw, required)
            if gap is not None:
                rep.add("TSL014",
                        f"{gap} — terms {list(required)} not guaranteed in "
                        f"the generated _cost.py for target {tname!r} "
                        "(serving admission would hit the analytic fallback)",
                        subject=f"primitive:{pname}",
                        location=f"target:{tname}")
    return rep
