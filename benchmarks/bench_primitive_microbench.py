"""Primitive-level microbenchmarks: generated TSL call vs direct jnp for the
hot primitives (zero-abstraction-overhead check at the primitive granularity
— the paper's 'compile-time deduction and code generation with zero overhead
for the runtime').
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import load_library
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rmsnorm import ref as rms_ref

from .common import emit, time_fn


def run() -> list[str]:
    lib = load_library("cpu_xla")
    rng = np.random.default_rng(0)
    out = []

    x = jnp.asarray(rng.normal(size=(4096, 1024)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(1024,)), jnp.float32)
    t_tsl = time_fn(jax.jit(lambda a: lib.ops.rmsnorm(a, w)), x)
    t_raw = time_fn(jax.jit(lambda a: rms_ref.rmsnorm(a, w)), x)
    emit("prim_rmsnorm_tsl", t_tsl, f"overhead={(t_tsl-t_raw)/t_raw*100:+.1f}%")
    emit("prim_rmsnorm_direct", t_raw, "")
    out.append(f"rmsnorm overhead {(t_tsl-t_raw)/t_raw*100:+.1f}%")

    q = jnp.asarray(rng.normal(size=(2, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 512, 64)), jnp.float32)
    t_tsl = time_fn(jax.jit(lambda a: lib.ops.flash_attention(a, k, v)), q, n_iter=10)
    t_raw = time_fn(jax.jit(lambda a: fa_ref.attention(a, k, v)), q, n_iter=10)
    emit("prim_attention_tsl", t_tsl, f"overhead={(t_tsl-t_raw)/t_raw*100:+.1f}%")
    emit("prim_attention_direct", t_raw, "")
    out.append(f"attention overhead {(t_tsl-t_raw)/t_raw*100:+.1f}%")

    # training path: flash_attention_bwd (ISSUE 3) — TSL (dq, dk, dv) vs the
    # oracle VJP that materializes the (Sq, Sk) matrix
    g = jnp.asarray(rng.normal(size=q.shape), jnp.float32)

    def _bwd_tsl(a):
        return lib.ops.flash_attention_bwd(a, k, v, g)

    def _bwd_raw(a):
        _, vjp = jax.vjp(lambda q_, k_, v_: fa_ref.attention(q_, k_, v_),
                         a, k, v)
        return vjp(g)

    t_tsl = time_fn(jax.jit(_bwd_tsl), q, n_iter=10)
    t_raw = time_fn(jax.jit(_bwd_raw), q, n_iter=10)
    emit("prim_attention_bwd_tsl", t_tsl,
         f"overhead={(t_tsl-t_raw)/t_raw*100:+.1f}%")
    emit("prim_attention_bwd_direct", t_raw, "")
    out.append(f"attention_bwd overhead {(t_tsl-t_raw)/t_raw*100:+.1f}%")

    # decode path: single-token GQA matvec against a padded KV cache
    qd = jnp.asarray(rng.normal(size=(2, 8, 1, 64)), jnp.float32)
    t_tsl = time_fn(jax.jit(lambda a: lib.ops.attention_decode(a, k, v)),
                    qd, n_iter=30)
    t_raw = time_fn(jax.jit(lambda a: fa_ref.attention_decode(a, k, v)),
                    qd, n_iter=30)
    emit("prim_attention_decode_tsl", t_tsl,
         f"overhead={(t_tsl-t_raw)/t_raw*100:+.1f}%")
    emit("prim_attention_decode_direct", t_raw, "")
    out.append(f"attention_decode overhead {(t_tsl-t_raw)/t_raw*100:+.1f}%")

    # serving prefill path (ISSUE 5): one 64-token continuation chunk against
    # a 512-row cache filled to 448 — the unified step's per-chunk unit
    qc = jnp.asarray(rng.normal(size=(2, 8, 64, 64)), jnp.float32)
    t_tsl = time_fn(
        jax.jit(lambda a: lib.ops.attention_prefill_chunk(a, k, v, kv_len=448)),
        qc, n_iter=30)
    t_raw = time_fn(
        jax.jit(lambda a: fa_ref.attention_chunked(a, k, v, causal=True,
                                                   kv_len=448, block_k=256)),
        qc, n_iter=30)
    emit("prim_attention_prefill_chunk_tsl", t_tsl,
         f"overhead={(t_tsl-t_raw)/t_raw*100:+.1f}% "
         f"({2 * 64 / (t_tsl * 1e-6):,.0f} prefill tok/s)")
    emit("prim_attention_prefill_chunk_direct", t_raw, "")
    out.append(f"attention_prefill_chunk overhead {(t_tsl-t_raw)/t_raw*100:+.1f}%")

    # speculative verify path (ISSUE 7): ONE ragged verify span (SV = k+1
    # rows per slot, per-slot kv_len vector) vs the k+1 SEQUENTIAL decode
    # steps it replaces when every draft is accepted — the throughput gap is
    # what the engine's cost-priced depth decision banks on
    spec_k = 4
    sv = spec_k + 1
    kv_vec = jnp.asarray([448, 320], jnp.int32)     # ragged slot fills
    qv = jnp.asarray(rng.normal(size=(2, 8, sv, 64)), jnp.float32)

    def _verify(a):
        return lib.ops.attention_verify(a, k, v, kv_len=kv_vec)

    def _decode_chain(a):
        o = a
        for _ in range(sv):                          # dependent, like decode
            o = fa_ref.attention_decode(o, k, v)
        return o

    t_verify = time_fn(jax.jit(_verify), qv, n_iter=30)
    t_chain = time_fn(jax.jit(_decode_chain), qd, n_iter=30)
    emit("prim_attention_verify_tsl", t_verify,
         f"span={sv} vs {sv} decode steps: {t_chain / t_verify:.2f}x "
         f"({2 * sv / (t_verify * 1e-6):,.0f} verified tok/s)")
    emit("prim_attention_decode_x5_direct", t_chain, "")
    out.append(f"attention_verify span {sv}: {t_chain / t_verify:.2f}x vs "
               f"{sv} sequential decode steps")

    # paged residency path (ISSUE 8): gathering a request's KV rows from a
    # scattered page pool (cache_page_read over a 64-entry block table) vs
    # the contiguous slice a slot table would read — the per-activation cost
    # paged serving pays for admitting on pages instead of worst-case lanes
    page = int(lib.ops.cache_page_read(
        jnp.zeros((1024, 1), jnp.float32), jnp.zeros((1,), jnp.int32)
    ).shape[0])
    n_tab = 64
    pool = jnp.asarray(rng.normal(size=(4 * n_tab * page, 256)), jnp.float32)
    # worst-case locality: pages strided across the pool
    tab = jnp.asarray(np.arange(n_tab, dtype=np.int32)[::-1] * 4 * page)
    t_paged = time_fn(jax.jit(lambda t_: lib.ops.cache_page_read(pool, t_)),
                      tab, n_iter=30)
    t_contig = time_fn(
        jax.jit(lambda p_: jax.lax.dynamic_slice_in_dim(p_, 0, n_tab * page)),
        pool, n_iter=30)
    rows_s = n_tab * page / (t_paged * 1e-6)
    emit("prim_cache_page_read_tsl", t_paged,
         f"page={page} x{n_tab} entries: {t_paged / t_contig:.2f}x vs "
         f"contiguous slice ({rows_s:,.0f} rows/s)")
    emit("prim_cache_rows_contiguous_direct", t_contig, "")
    out.append(f"cache_page_read page {page}: {t_paged / t_contig:.2f}x vs "
               "contiguous slice")

    # fused paged decode (ISSUE 9): decode DIRECTLY against the page pool
    # through the block table, vs what the lane path pays per step-after-
    # activation — gather the pages into a contiguous lane (cache_page_read,
    # the page size the bench selected above) THEN run contiguous decode.
    # Same page size, same row count, same interleaved worst-case locality;
    # the gather bytes are data movement the fused primitive never does.
    # The fused primitive is taken from the BENCH-SELECTED library (the
    # pages-per-step/block_k winner for this host), because the serving
    # engine runs exactly that selection.
    # Pools, tables, and lane buffers are passed as TRACED jit arguments on
    # both sides (closing over them lets XLA constant-fold the page gathers
    # — a regime the serving engine never runs in: its pools are live device
    # state threaded through every step).
    lib_b = load_library("cpu_xla", use_bench_selection=True)
    kh, d = 2, 64
    n_per = max(2048 // page, 1)            # pages per slot: ~2k-row caches
    rows = n_per * page
    k_pool = jnp.asarray(rng.normal(size=(kh, 2 * n_per + 1, page, d)),
                         jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(kh, 2 * n_per + 1, page, d)),
                         jnp.float32)
    tabs = jnp.asarray(np.stack([np.arange(n_per) * 2 + 1,
                                 np.arange(n_per) * 2 + 2]).astype(np.int32))
    kvl = jnp.asarray([rows, rows], jnp.int32)
    t_fused = time_fn(
        jax.jit(lambda a_, kp_, vp_, t_, l_: lib_b.ops.attention_decode_paged(
            a_, kp_, vp_, t_, kv_len=l_)),
        qd, k_pool, v_pool, tabs, kvl, n_iter=30)

    # lane layout: one flat (n_pages*page, KH*D) pool per k/v, rows gathered
    # per slot then reshaped into the contiguous (B, KH, S, D) cache view
    flat_k = jnp.asarray(
        rng.normal(size=((2 * n_per + 1) * page, kh * d)), jnp.float32)
    flat_v = jnp.asarray(
        rng.normal(size=((2 * n_per + 1) * page, kh * d)), jnp.float32)
    row_tabs = tabs * page                  # cache_page_read takes row offsets

    def _gather_then_decode(a_, fk_, fv_, t_):
        kl = jnp.stack([lib.ops.cache_page_read(fk_, t_[i])
                        for i in range(2)]).reshape(2, rows, kh, d)
        vl = jnp.stack([lib.ops.cache_page_read(fv_, t_[i])
                        for i in range(2)]).reshape(2, rows, kh, d)
        return fa_ref.attention_decode(a_, jnp.swapaxes(kl, 1, 2),
                                       jnp.swapaxes(vl, 1, 2))

    t_gather = time_fn(jax.jit(_gather_then_decode),
                       qd, flat_k, flat_v, row_tabs, n_iter=30)
    kc = jnp.asarray(rng.normal(size=(2, kh, rows, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(2, kh, rows, d)), jnp.float32)
    t_contig = time_fn(
        jax.jit(lambda a_, kc_, vc_: fa_ref.attention_decode(a_, kc_, vc_)),
        qd, kc, vc, n_iter=30)
    gather_bytes = 2 * 2 * rows * kh * d * 4    # B x {k,v} x rows x KH x D
    emit("prim_attention_decode_paged_tsl", t_fused,
         f"page={page} x{n_per}/slot: {t_gather / t_fused:.2f}x vs "
         f"gather+decode ({gather_bytes:,} gather B/step eliminated)")
    emit("prim_attention_decode_gather_direct", t_gather, "")
    emit("prim_attention_decode_contig_direct", t_contig, "")
    out.append(f"attention_decode_paged: {t_gather / t_fused:.2f}x vs "
               f"gather+decode, {t_contig / t_fused:.2f}x vs contiguous "
               f"decode ({gather_bytes:,} gather bytes/step eliminated)")

    a = jnp.asarray(rng.normal(size=(1024, 1024)), jnp.bfloat16)
    b = jnp.asarray(rng.normal(size=(1024, 1024)), jnp.bfloat16)
    t_tsl = time_fn(jax.jit(lambda x_: lib.ops.matmul(x_, b)), a)
    t_raw = time_fn(jax.jit(lambda x_: jnp.matmul(x_, b)), a)
    emit("prim_matmul_tsl", t_tsl, f"overhead={(t_tsl-t_raw)/t_raw*100:+.1f}%")
    emit("prim_matmul_direct", t_raw, "")
    out.append(f"matmul overhead {(t_tsl-t_raw)/t_raw*100:+.1f}%")
    return out


if __name__ == "__main__":
    run()
