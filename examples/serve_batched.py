"""Batched-serving driver (deliverable (b)): prefill + multi-step decode with
wave-style continuous batching, over two architectures (attention KV cache vs
RWKV recurrent state) to show the uniform serving surface.

    PYTHONPATH=src python examples/serve_batched.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.serve import main as serve_main


def main():
    print("[example] serving qwen1.5-0.5b-reduced (KV-cache decode)")
    r1 = serve_main(["--arch", "qwen1.5-0.5b", "--reduced", "--batch", "4",
                     "--prompt-len", "32", "--gen-len", "32",
                     "--requests", "8"])
    print("[example] serving rwkv6-7b-reduced (recurrent-state decode)")
    r2 = serve_main(["--arch", "rwkv6-7b", "--reduced", "--batch", "4",
                     "--prompt-len", "32", "--gen-len", "32",
                     "--requests", "8"])
    print(f"[example] qwen decode t/s: {r1['decode_tokens_per_s']:,.0f}; "
          f"rwkv decode t/s: {r2['decode_tokens_per_s']:,.0f}")


if __name__ == "__main__":
    main()
