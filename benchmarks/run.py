"""Benchmark harness entry point — one module per paper table/figure
(deliverable (d)). Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig10,fig12,...]
"""

from __future__ import annotations

import argparse
import sys
import traceback

SUITES = {
    "fig10": ("benchmarks.bench_fig10_applicability",
              "Fig 10: generated vs hand-written relative runtime"),
    "fig12": ("benchmarks.bench_fig12_blocksize",
              "Fig 12: throughput vs block (vector) size"),
    "loc": ("benchmarks.bench_extensibility_loc",
            "§5.3: extensibility LOC accounting"),
    "adaptive": ("benchmarks.bench_adaptive_selection",
                 "§4.2: benchmark-driven adaptive variant selection"),
    "prim": ("benchmarks.bench_primitive_microbench",
             "primitive-level zero-overhead check"),
    "roofline": ("benchmarks.roofline_report",
                 "dry-run roofline summary (reads experiments/dryrun)"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else set(SUITES)
    failures = []
    for key, (module, desc) in SUITES.items():
        if key not in want:
            continue
        print(f"# --- {key}: {desc}")
        try:
            import importlib

            mod = importlib.import_module(module)
            mod.run()
        except Exception:
            failures.append(key)
            traceback.print_exc()
    if failures:
        print(f"# FAILED suites: {failures}")
        sys.exit(1)
    print("# all benchmark suites completed")


if __name__ == "__main__":
    main()
