"""Pure-jnp oracle: full-materialization attention (GQA-aware, causal opt.)

Also the cpu_xla TSL implementation — XLA fuses this well enough on CPU, and
it is the ground truth the Pallas kernel must match bit-for-bit up to f32
accumulation differences.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand_kv(k, groups: int):
    # (B, KH, S, D) -> (B, KH*groups, S, D)
    b, kh, s, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, kh, groups, s, d)).reshape(b, kh * groups, s, d)


def attention(q, k, v, *, causal: bool = True, scale: float | None = None,
              kv_len: int | None = None):
    """q: (B,H,Sq,D); k,v: (B,KH,Sk,D) with H % KH == 0. Returns (B,H,Sq,D).

    kv_len masks out key positions >= kv_len (padding) AND, like the Pallas
    kernel and :func:`attention_chunked`, sets the causal alignment: the last
    q row sits at logical position kv_len - 1, not Sk - 1 (prefill
    continuation against a padded cache)."""
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    assert h % kh == 0, (h, kh)
    if h != kh:
        k = _expand_kv(k, h // kh)
        v = _expand_kv(v, h // kh)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    neg = jnp.float32(-1e30)
    if causal:
        end = kv_len if kv_len is not None else sk
        qi = jnp.arange(sq)[:, None] + (end - sq)  # align ends (prefill/decode)
        ki = jnp.arange(sk)[None, :]
        s = jnp.where(qi >= ki, s, neg)
    if kv_len is not None:
        s = jnp.where(jnp.arange(sk)[None, :] < kv_len, s, neg)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p / jnp.maximum(l, 1e-30),
                   v.astype(jnp.float32))
    # fully-masked rows (e.g. sq > sk under ends-aligned causal) -> 0, matching
    # the kernel's l==0 guard rather than a degenerate uniform average
    o = jnp.where(m > -1e29, o, 0.0)
    return o.astype(q.dtype)


def attention_chunked(q, k, v, *, causal: bool = True, scale: float | None = None,
                      kv_len=None, block_k: int = 1024):
    """Flash-style chunked attention in PURE jnp: lax.scan over key blocks
    with an online-softmax carry. The (Sq, Sk) score matrix never
    materializes — per-step working set is (Sq, block_k), so the XLA memory
    roofline drops from O(S²) to O(S·bk). Used as the specialized cpu_xla
    TSL variant (§Perf yi-34b iteration); the Pallas kernel is the same
    algorithm with explicit VMEM tiling.

    ``kv_len`` may be a scalar or a (B,) vector of per-sequence cache fills
    (continuous batching: each slot sits at its own position).
    """
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    assert h % kh == 0
    g = h // kh
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kv_len = kv_len if kv_len is not None else sk
    kv_vec = jnp.broadcast_to(jnp.asarray(kv_len), (b,))   # (B,) per-sequence
    bk = min(block_k, sk)
    pad = (-sk) % bk
    if pad:
        zp = ((0, 0), (0, 0), (0, pad), (0, 0))
        k = jnp.pad(k, zp)
        v = jnp.pad(v, zp)
    nk = (sk + pad) // bk
    qg = q.reshape(b, kh, g, sq, d).astype(jnp.float32)
    kb = k.astype(jnp.float32).reshape(b, kh, nk, bk, d).transpose(2, 0, 1, 3, 4)
    vb = v.astype(jnp.float32).reshape(b, kh, nk, bk, d).transpose(2, 0, 1, 3, 4)
    q_pos = jnp.arange(sq)[None, :] + (kv_vec[:, None] - sq)   # (B,Sq)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kt, vt, ki = inp                                  # (B,KH,bk,D) x2
        s = jnp.einsum("bkgqd,bked->bkgqe", qg, kt) * scale  # (B,KH,G,Sq,bk)
        k_pos = ki * bk + jnp.arange(bk)
        mask = k_pos[None, None, :] < kv_vec[:, None, None]      # (B,1,bk)
        if causal:
            mask = jnp.logical_and(mask, q_pos[:, :, None] >= k_pos[None, None, :])
        s = jnp.where(mask[:, None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bkgqe,bked->bkgqd", p, vt)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kh, g, sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kh, g, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, kh, g, sq, d), jnp.float32)
    # unroll follows the dry-run cost-measurement flag (XLA cost analysis
    # counts while-loop bodies once; see nn/flags.py)
    from repro.nn import flags as _nn_flags

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nk)),
                                  unroll=_nn_flags.scan_unroll())
    o = acc / jnp.maximum(l, 1e-30)
    o = jnp.where(l > 0.0, o, 0.0)
    return o.reshape(b, h, sq, d).astype(q.dtype)


def attention_verify(q, k_cache, v_cache, *, kv_len=None, scale: float | None = None,
                     block_k: int = 1024):
    """Speculative-decoding verify span: q (B,H,SV,D) holds the pending token
    plus the drafted continuation for each slot; the caches (B,KH,S,D) already
    contain the span's K/V rows written at [kv_len-SV, kv_len). Causality is
    ends-aligned at ``kv_len`` exactly like :func:`attention_chunked` — row j
    of the span attends to the cache up to position kv_len - SV + j — so with
    SV == 1 this IS the decode step, and the accepted-prefix contract holds
    row-by-row: row j's output is independent of rows > j.

    ``kv_len`` may be a (B,) vector (the slot table: every slot sits at its
    own fill). Shares the online-softmax chunked backend; ``block_k`` is the
    bench-owned key-block candidate knob.
    """
    return attention_chunked(q, k_cache, v_cache, causal=True, scale=scale,
                             kv_len=kv_len, block_k=block_k)


def attention_decode(q, k_cache, v_cache, *, kv_len=None, scale: float | None = None):
    """Single-token decode: q (B,H,1,D) vs caches (B,KH,S,D).

    GQA-grouped formulation: q is reshaped to (B,KH,G,D) and contracted
    against the cache directly — the KV cache is NEVER head-expanded (the
    broadcast would force GSPMD to reshard/gather the full cache). With the
    cache sequence-sharded (sequence-parallel decode), the softmax reductions
    become small cross-shard psums. ``kv_len`` may be traced (cache fill) and
    may be a (B,) vector of per-sequence fills (continuous batching: each
    slot sits at its own position). Memory-bound matvec — jnp is the right
    tool on every target.
    """
    from repro.dist.sharding import logical_constraint

    b, h, _, d = q.shape
    _, kh, s_max, _ = k_cache.shape
    g = h // kh
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(b, kh, g, d).astype(jnp.float32)
    k_cache = logical_constraint(k_cache, "batch", None, "kvseq", None)
    v_cache = logical_constraint(v_cache, "batch", None, "kvseq", None)
    s = jnp.einsum("bkgd,bksd->bkgs", qg,
                   k_cache.astype(jnp.float32)) * scale
    s = logical_constraint(s, "batch", None, None, "kvseq")
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        if kvl.ndim:                       # (B,) per-sequence cache fills
            kvl = kvl.reshape(b, 1, 1, 1)
        mask = jnp.arange(s_max)[None, None, None, :] < kvl
        s = jnp.where(mask, s, jnp.float32(-1e30))
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, 1, d).astype(q.dtype)
