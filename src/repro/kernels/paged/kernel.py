"""Pallas TPU kernel: fused paged attention over a block table.

The lane-gather elimination behind the ``attention_decode_paged`` /
``attention_verify_paged`` UPD primitives: instead of activating a slot's
pages into a contiguous lane and running ``attention_decode`` there, the
kernel walks the PAGE POOL directly. The block table and per-slot kv_len
arrive as scalar-prefetch operands (``pltpu.PrefetchScalarGridSpec``), so
each K/V BlockSpec index map can translate a *logical* key-block index into
a *physical* pool row before the DMA is issued — the page indirection is
folded into the Pallas pipeline itself and the only HBM traffic is the
touched pages.

Grid: (B, KH, n_j) where n_j = max_pages * (page // block_k); the j axis is
"arbitrary" (sequential) so the online-softmax (m, l, acc) scratch carries
across key blocks exactly as in the flash-attention forward. GQA is folded
by shaping q as (B, KH, group * SQ, D): all of a KV head's query heads ride
in the q block's row axis, so each pool page is fetched once per KV head.

Blocks past a slot's kv_len are skipped by a block-level early exit on the
prefetched length; their table entries must still hold a VALID page id (the
serving layer points them at a scratch page) because the index map runs
unconditionally. kv_len == 0 rows finalize to exactly 0 (l stays 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30
_LANES = 128


def _paged_kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, sq: int,
                  bk: int, n_j: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    kvl = len_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # key blocks are visited in logical order (page-major, sub-block minor),
    # so this block covers logical key positions [j*bk, (j+1)*bk)
    @pl.when(j * bk < kvl)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)              # (rq, D)
        k = k_ref[0].astype(jnp.float32)                 # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        rq = q.shape[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (rq, bk)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (rq, bk), 1)
        row = jax.lax.broadcasted_iota(jnp.int32, (rq, bk), 0)
        # span rows are ends-aligned at kv_len: row r sits at kvl - sq + r%sq
        q_pos = kvl - sq + jax.lax.rem(row, sq)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

        m_prev = m_scr[:, :1]                            # (rq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(k_pos <= q_pos, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_scr[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == n_j - 1)
    def _finalize():
        l = l_scr[:, :1]
        o = acc_scr[...] / jnp.maximum(l, 1e-30)
        o_ref[0, 0] = jnp.where(l > 0.0, o, 0.0).astype(o_ref.dtype)


def paged_attention_4d(q, k_flat, v_flat, tables, kv_len, *, sq: int,
                       page: int, block_k: int, scale: float | None = None,
                       interpret: bool = False):
    """q: (B, KH, RQ, D) — RQ = group*sq padded to a sublane multiple;
    k_flat/v_flat: (KH, n_pages*page, D) row-flattened pools; tables: (B, P)
    int32 page ids; kv_len: (B,) int32. Returns (B, KH, RQ, D)."""
    b, kh, rq, d = q.shape
    assert page % block_k == 0, (page, block_k)
    spp = page // block_k                       # key sub-blocks per page
    n_p = tables.shape[1]
    n_j = n_p * spp
    sc = scale if scale is not None else 1.0 / (d ** 0.5)

    def kv_idx(b_, h_, j, tab, _len):
        # physical block index into the row-flattened pool, in bk units:
        # page id * sub-blocks-per-page + sub-block within the page
        return (h_, tab[b_, j // spp] * spp + jax.lax.rem(j, spp), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, n_j),
        in_specs=[
            pl.BlockSpec((1, 1, rq, d), lambda b_, h_, j, tab, ln: (b_, h_, 0, 0)),
            pl.BlockSpec((1, block_k, d), kv_idx),
            pl.BlockSpec((1, block_k, d), kv_idx),
        ],
        out_specs=pl.BlockSpec((1, 1, rq, d),
                               lambda b_, h_, j, tab, ln: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rq, _LANES), jnp.float32),   # running max
            pltpu.VMEM((rq, _LANES), jnp.float32),   # running denominator
            pltpu.VMEM((rq, d), jnp.float32),        # output accumulator
        ],
    )
    kernel = functools.partial(_paged_kernel, scale=sc, sq=sq, bk=block_k,
                               n_j=n_j)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="tsl_paged_attention",
    )(jnp.asarray(tables, jnp.int32), jnp.asarray(kv_len, jnp.int32),
      q, k_flat, v_flat)
