"""internvl2-2b [vlm]: InternViT frontend (STUB) + InternLM2-1.8B backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821; hf]

The modality frontend is a stub per the task brief: input_specs() provides
precomputed patch embeddings (vision_prefix tokens of width d_model) that the
backbone consumes alongside token embeddings.
"""

from .arch import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    head_dim=128,
    vision_prefix=256,            # one ViT tile of patch embeddings
    rope_theta=1e6,
    source="arXiv:2404.16821; hf",
)
