"""int8 absmax gradient compression with error feedback.

``compress_decompress`` models the wire format: per-row (last-axis) absmax
scaling to int8 and back. Quantization error per element is bounded by
scale/2 = amax/254. ``ErrorFeedback`` carries the residual so the scheme is
lossless in expectation: quantized + residual == input + residual_in.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_absmax_int8(x):
    """Per-row (last-axis) absmax int8 quantization: returns ``(q, scale)``
    with ``q`` int8 in [-127, 127] and ``scale`` f32 keeping the last axis
    as size 1. The wire/page format shared by gradient compression and the
    serve layer's int8 cache pages (``repro.serve.paging``). Error per
    element is bounded by scale/2 = amax/254."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_absmax_int8(q, scale, dtype=jnp.float32):
    """Inverse of :func:`quantize_absmax_int8` (up to the bounded error)."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _quantize_roundtrip(x):
    """x -> dequantize(quantize_int8(x)), computed in f32."""
    q, scale = quantize_absmax_int8(x)
    return q.astype(jnp.float32) * scale


def compress_decompress(tree):
    """Quantize-dequantize every leaf. Returns (tree', max_abs_error)."""
    out = jax.tree.map(lambda g: _quantize_roundtrip(g).astype(g.dtype), tree)
    errs = jax.tree.map(
        lambda a, b: jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))),
        out, tree)
    leaves = jax.tree.leaves(errs)
    err = jnp.max(jnp.stack(leaves)) if leaves else jnp.float32(0)
    return out, err


class ErrorFeedback:
    """Residual bookkeeping: feed quantization error back into the next step."""

    @staticmethod
    def init(tree):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)

    @staticmethod
    def apply(tree, residual):
        """Returns (quantized, new_residual) with the identity
        quantized + new_residual == tree + residual."""
        corrected = jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, tree, residual)
        quantized = jax.tree.map(
            lambda c, g: _quantize_roundtrip(c).astype(g.dtype), corrected, tree)
        # residual measured against the DTYPE-CAST value actually emitted, so
        # the cast's own rounding also feeds back (exact identity on any dtype)
        new_residual = jax.tree.map(
            lambda c, q: c - q.astype(jnp.float32), corrected, quantized)
        return quantized, new_residual
