"""Compile-only smoke coverage for runs_on_host:false targets (ROADMAP item).

The pallas_tpu library can't execute on a CPU-only container, but its
generated bodies CAN be traced: ``jax.eval_shape`` abstract-evaluates every
``pallas_call`` with ``interpret=False``, which traces the kernel function
into a jaxpr — shape errors, rank bugs and dtype mismatches in the generated
Mosaic-path code surface here without a TPU. Full Mosaic lowering/execution
additionally runs when a TPU backend is actually present (opt-in CI lane).
"""

import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="module")
def lib_tpu():
    from repro.core import load_library

    return load_library("pallas_tpu")


def test_tpu_library_generates_and_imports(lib_tpu):
    assert lib_tpu.TARGET_NAME == "pallas_tpu"
    assert not lib_tpu.TARGET.runs_on_host
    assert lib_tpu.TARGET.has("tpu", "mxu")


def test_tpu_selection_uses_pallas_kernels(lib_tpu):
    """The compiled-TPU SRU must route the hot primitives through the Pallas
    definitions (interpret=False), not the portable jnp fallbacks."""
    import json
    from pathlib import Path

    man = json.loads(
        (Path(lib_tpu.__file__).parent / "_manifest.json").read_text())
    for prim in ("rmsnorm", "softmax", "hadd", "swiglu", "flash_attention"):
        flags = man["primitives"][prim]["float32"]["required_flags"]
        assert "pallas" in flags, (prim, flags)


@pytest.mark.parametrize("prim,shapes", [
    ("rmsnorm", [(8, 256), (256,)]),
    ("softmax", [(8, 256)]),
    ("swiglu", [(8, 256), (8, 256)]),
    ("hadd", [(8, 256)]),
    ("flash_attention", [(1, 2, 128, 64)] * 3),
])
def test_tpu_pallas_bodies_trace_without_execution(lib_tpu, prim, shapes):
    """Abstract-evaluate each Pallas-routed primitive: traces the kernel body
    with interpret=False, no TPU needed, no execution performed."""
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    fn = getattr(lib_tpu.ops, prim)
    out = jax.eval_shape(fn, *args)
    leaves = jax.tree_util.tree_leaves(out)
    assert leaves and all(leaf.dtype == jnp.float32 for leaf in leaves)


def test_tpu_pallas_bodies_trace_bf16(lib_tpu):
    x = jax.ShapeDtypeStruct((16, 512), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((512,), jnp.bfloat16)
    assert jax.eval_shape(lib_tpu.ops.rmsnorm, x, w).dtype == jnp.bfloat16
    assert jax.eval_shape(lib_tpu.ops.softmax, x).shape == (16, 512)


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="Mosaic lowering needs a real TPU backend")
def test_tpu_pallas_bodies_lower_on_tpu(lib_tpu):  # pragma: no cover
    """Opt-in lane: on a real TPU, lower (compile) without executing."""
    x = jnp.ones((8, 256), jnp.float32)
    w = jnp.ones((256,), jnp.float32)
    jax.jit(lib_tpu.ops.rmsnorm).lower(x, w).compile()
