"""Public wrapper for the fused SwiGLU kernel."""

from __future__ import annotations

from functools import partial

import jax

from ..common import pad_to, round_up, sublane_multiple
from . import kernel, ref


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def swiglu(gate, up, *, block_rows: int = 256, interpret: bool = False):
    orig = gate.shape
    d = orig[-1]
    rows = 1
    for s in orig[:-1]:
        rows *= s
    g2 = gate.reshape(rows, d)
    u2 = up.reshape(rows, d)
    sub = sublane_multiple(gate.dtype)
    bm = min(block_rows, round_up(rows, sub))
    g2, n = pad_to(g2, 0, bm)
    u2, _ = pad_to(u2, 0, bm)
    out = kernel.swiglu_2d(g2, u2, block_rows=bm, interpret=interpret)
    return out[:n].reshape(orig)


__all__ = ["swiglu", "ref"]
