"""Jinja2 template engine environment (paper §3.2 ③).

Two-stage rendering (paper: "we split our generation GPO into two stages"):

* **stage 1** — every implementation body from the UPD is itself treated as a
  Jinja2 template and rendered against {sru, ctype, dtype helpers, primitive}.
  This is what lets a single definition cover all ctypes (paper's Neon
  ``hadd`` one-liner).
* **stage 2** — structural library templates (``templates/*.j2``) are rendered
  with the selected, stage-1-rendered implementations.
"""

from __future__ import annotations

import textwrap
from pathlib import Path
from typing import Any

import jinja2

TEMPLATE_DIR = Path(__file__).resolve().parent / "templates"

# dtype helper table exposed to stage-1 templates
_DTYPE_INFO = {
    "float32": {"np": "jnp.float32", "short": "f32", "bits": 32, "kind": "float"},
    "bfloat16": {"np": "jnp.bfloat16", "short": "bf16", "bits": 16, "kind": "float"},
    "float16": {"np": "jnp.float16", "short": "f16", "bits": 16, "kind": "float"},
    "int32": {"np": "jnp.int32", "short": "i32", "bits": 32, "kind": "int"},
    "int16": {"np": "jnp.int16", "short": "i16", "bits": 16, "kind": "int"},
    "int8": {"np": "jnp.int8", "short": "i8", "bits": 8, "kind": "int"},
    "uint32": {"np": "jnp.uint32", "short": "u32", "bits": 32, "kind": "uint"},
    "uint16": {"np": "jnp.uint16", "short": "u16", "bits": 16, "kind": "uint"},
    "uint8": {"np": "jnp.uint8", "short": "u8", "bits": 8, "kind": "uint"},
}


def dtype_info(ctype: str) -> dict[str, Any]:
    if ctype not in _DTYPE_INFO:
        raise KeyError(f"unknown ctype {ctype!r}; known: {sorted(_DTYPE_INFO)}")
    return dict(_DTYPE_INFO[ctype], name=ctype)


def _indent(text: str, n: int = 4, first: bool = False) -> str:
    pad = " " * n
    lines = text.splitlines()
    out = []
    for i, ln in enumerate(lines):
        if i == 0 and not first:
            out.append(ln)
        else:
            out.append(pad + ln if ln.strip() else ln)
    return "\n".join(out)


def make_environment() -> jinja2.Environment:
    env = jinja2.Environment(
        loader=jinja2.FileSystemLoader(str(TEMPLATE_DIR)),
        undefined=jinja2.StrictUndefined,
        trim_blocks=True,
        lstrip_blocks=True,
        keep_trailing_newline=True,
    )
    env.filters["indent_body"] = lambda s, n=4, first=True: _indent(s, n, first)
    env.filters["dedent"] = textwrap.dedent
    env.globals["dtype_info"] = dtype_info
    return env


_ENV: jinja2.Environment | None = None


def environment() -> jinja2.Environment:
    global _ENV
    if _ENV is None:
        _ENV = make_environment()
    return _ENV


def render_stage1(body: str, *, sru: dict, ctype: str, primitive: str,
                  params: tuple[str, ...]) -> str:
    """Render one implementation body against its target data (stage 1)."""
    tmpl = environment().from_string(body)
    return tmpl.render(
        sru=sru,
        ctype=ctype,
        dtype=dtype_info(ctype),
        primitive=primitive,
        params=params,
    ).rstrip("\n")


def render_template(name: str, **ctx: Any) -> str:
    return environment().get_template(name).render(**ctx)
