"""§Perf helper: compare tagged dry-run records (hypothesis→change→measure
iterations) for the hillclimbed cells."""

from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load(arch: str, shape: str, mesh: str = "16x16", tag: str = "") -> dict | None:
    suffix = f"_{tag}" if tag else ""
    f = DRYRUN_DIR / f"{arch}_{shape}_{mesh}{suffix}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def compare(arch: str, shape: str, tags: list[str], mesh: str = "16x16") -> str:
    rows = [f"### {arch} × {shape} ({mesh})",
            "| iteration | compute | memory(adj) | collective | dominant | "
            "bound | Δbound vs prev |",
            "|---|---|---|---|---|---|---|"]
    prev = None
    for tag in tags:
        r = load(arch, shape, mesh, tag)
        if r is None or r.get("status") != "ok":
            rows.append(f"| {tag or 'baseline'} | — | — | — | — | missing | — |")
            continue
        t = r["roofline"]
        delta = ""
        if prev is not None:
            delta = f"{(t['roofline_bound_s'] - prev) / prev * 100:+.1f}%"
        rows.append(
            f"| {tag or 'baseline'} | {t['compute_s']:.4f} | {t['memory_s']:.4f} | "
            f"{t['collective_s']:.4f} | {t['dominant']} | "
            f"{t['roofline_bound_s']:.4f}s | {delta} |")
        prev = t["roofline_bound_s"]
    return "\n".join(rows)


if __name__ == "__main__":
    import sys

    print(compare(sys.argv[1], sys.argv[2], [""] + sys.argv[3:]))
