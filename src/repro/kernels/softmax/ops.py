"""Public wrapper for the softmax kernel."""

from __future__ import annotations

from functools import partial

import jax

from ..common import pad_to, round_up, sublane_multiple
from . import kernel, ref


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def softmax(x, *, block_rows: int = 256, interpret: bool = False):
    """Stable softmax over the last axis, arbitrary rank.

    Row padding uses -inf-like fill so padded rows normalize harmlessly."""
    orig = x.shape
    d = orig[-1]
    rows = 1
    for s in orig[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    sub = sublane_multiple(x.dtype)
    bm = min(block_rows, round_up(rows, sub))
    x2, n = pad_to(x2, 0, bm)
    out = kernel.softmax_2d(x2, block_rows=bm, interpret=interpret)
    return out[:n].reshape(orig)


__all__ = ["softmax", "ref"]
