"""Pallas tiling lint (TSL03x): BlockSpec/grid geometry vs the target SRU.

The kernels hard-code tile shapes; the SRUs declare the hardware geometry
(``sublanes`` × ``lanes`` VREG tiling, MXU shape) — and nothing compared the
two until now. This analyzer AST-walks kernel modules (``kernels/**/kernel.py``
and stage-1-rendered UPD pallas bodies) and checks:

* **TSL030** — constant ``pl.BlockSpec`` block dims must align to the target
  tiling: last dim a multiple of ``lanes``, second-to-last a multiple of
  ``sublanes``. Dims of 1 are broadcast/scalar blocks and exempt; symbolic
  dims are resolved by constant propagation over module constants, integer
  keyword defaults and simple assignments — what cannot be resolved is not
  guessed at.
* **TSL031** — a ``grid`` computed with floor division (``x // b``) silently
  drops remainder rows unless the module also guards divisibility: any
  ``x % b`` over the same operand pair (asserts count) or a ceil-div. The
  guard search is module-wide because kernels commonly assert in a sibling
  prep function.
* **TSL032** — ``dot``/``dot_general`` without ``preferred_element_type``
  accumulates in the input dtype; bf16 MXU accumulation loses ~8 bits per
  256-term sum. (``jnp.einsum`` gets the same check via its
  ``preferred_element_type`` keyword.)
* **TSL033** — paged-memory primitives (``serve: {page_sizes: [...]}``)
  gather whole pages as (page, row) slabs, so every declared page-size
  candidate must be a positive multiple of the SRU ``sublanes`` of every
  target the primitive covers — otherwise each gather relayouts and each
  scatter wastes VREG rows on that target.
"""

from __future__ import annotations

import ast
from pathlib import Path

from .findings import AnalysisReport
from .render import RenderedBody

_DOT_FUNCS = {"dot", "dot_general", "einsum"}


# -- constant propagation -----------------------------------------------------

def _const_eval(node: ast.expr, env: dict[str, int]) -> int | None:
    """Evaluate an int-valued expression over known constants, or None."""
    if isinstance(node, ast.Constant):
        return node.value if isinstance(node.value, int) and not isinstance(
            node.value, bool) else None
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _const_eval(node.operand, env)
        return -v if v is not None else None
    if isinstance(node, ast.BinOp):
        lo = _const_eval(node.left, env)
        ro = _const_eval(node.right, env)
        if lo is None or ro is None:
            return None
        try:
            if isinstance(node.op, ast.Add):
                return lo + ro
            if isinstance(node.op, ast.Sub):
                return lo - ro
            if isinstance(node.op, ast.Mult):
                return lo * ro
            if isinstance(node.op, ast.FloorDiv):
                return lo // ro
            if isinstance(node.op, ast.Mod):
                return lo % ro
        except (ZeroDivisionError, ValueError):
            return None
    return None


def _assign_env(body: list[ast.stmt], env: dict[str, int]) -> dict[str, int]:
    """Fold simple ``NAME = <const expr>`` assignments into ``env``."""
    env = dict(env)
    for stmt in body:
        if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            v = _const_eval(stmt.value, env)
            if v is not None:
                env[stmt.targets[0].id] = v
    return env


def _function_env(fn: ast.FunctionDef, module_env: dict[str, int]
                  ) -> dict[str, int]:
    env = dict(module_env)
    args = fn.args
    # integer keyword defaults bind their parameter name (callers usually
    # keep the default; a smaller runtime value only tightens alignment)
    pos = args.posonlyargs + args.args
    for a, dflt in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        v = _const_eval(dflt, {})
        if v is not None:
            env[a.arg] = v
    for a, dflt in zip(args.kwonlyargs, args.kw_defaults):
        if dflt is not None:
            v = _const_eval(dflt, {})
            if v is not None:
                env[a.arg] = v
    return _assign_env(fn.body, env)


# -- extraction ---------------------------------------------------------------

def _is_blockspec(call: ast.Call) -> bool:
    f = call.func
    return (isinstance(f, ast.Name) and f.id == "BlockSpec") or (
        isinstance(f, ast.Attribute) and f.attr == "BlockSpec")


def _dot_call_name(call: ast.Call) -> str | None:
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr in _DOT_FUNCS:
        return f.attr
    if isinstance(f, ast.Name) and f.id in _DOT_FUNCS:
        return f.id
    return None


def _mod_pairs(tree: ast.AST) -> set[tuple[str, str]]:
    """All ``x % b`` operand pairs anywhere in the module (guards)."""
    return {
        (ast.unparse(n.left), ast.unparse(n.right))
        for n in ast.walk(tree)
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod)
    }


def _grid_exprs(fn: ast.FunctionDef) -> list[ast.expr]:
    """Expressions that feed a ``grid``: ``grid=...`` keywords and
    assignments to a name called ``grid``."""
    out: list[ast.expr] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "grid":
                    out.append(kw.value)
        elif isinstance(node, ast.Assign):
            if any(isinstance(t, ast.Name) and t.id == "grid"
                   for t in node.targets):
                out.append(node.value)
    return out


def _check_module(tree: ast.Module, rep: AnalysisReport, *, subject: str,
                  locate, sublanes: int, lanes: int) -> None:
    """Run all three tiling checks over one parsed module.

    ``locate(lineno)`` renders the finding location string, letting kernel
    files report ``line N`` and UPD bodies report ``def[i] line N``."""
    module_env = _assign_env(tree.body, {})
    guards = _mod_pairs(tree)
    functions = [n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)]

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = _dot_call_name(node)
            if name and not any(kw.arg == "preferred_element_type"
                                for kw in node.keywords):
                rep.add("TSL032",
                        f"{name}(...) without preferred_element_type= — "
                        "accumulates in the input dtype",
                        subject=subject, location=locate(node.lineno))

    for fn in functions:
        env = _function_env(fn, module_env)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if _is_blockspec(node) and node.args and isinstance(
                    node.args[0], ast.Tuple):
                dims = node.args[0].elts
                for axis, spec in (((-1), lanes), ((-2), sublanes)):
                    if len(dims) < -axis:
                        continue
                    v = _const_eval(dims[axis], env)
                    if v is not None and v > 1 and v % spec != 0:
                        which = "last" if axis == -1 else "second-to-last"
                        rep.add("TSL030",
                                f"BlockSpec {which} block dim "
                                f"{ast.unparse(dims[axis])} = {v} is not a "
                                f"multiple of {spec} "
                                f"({'lanes' if axis == -1 else 'sublanes'})",
                                subject=subject,
                                location=locate(node.lineno))
        for expr in _grid_exprs(fn):
            for node in ast.walk(expr):
                if isinstance(node, ast.BinOp) and isinstance(
                        node.op, ast.FloorDiv):
                    pair = (ast.unparse(node.left), ast.unparse(node.right))
                    lv = _const_eval(node.left, env)
                    rv = _const_eval(node.right, env)
                    if lv is not None and rv and lv % rv == 0:
                        continue        # statically exact division
                    if pair not in guards:
                        rep.add("TSL031",
                                f"grid uses {pair[0]} // {pair[1]} but no "
                                f"{pair[0]} % {pair[1]} guard exists in the "
                                "module — remainder rows are dropped",
                                subject=subject,
                                location=locate(node.lineno))


# -- entry points -------------------------------------------------------------

def _serve_candidates(prim, plural: str, singular: str) -> list[int]:
    serve = (prim.extra or {}).get("serve") or {}
    vals = serve.get(plural)
    if vals is None:
        vals = [serve[singular]] if singular in serve else []
    return [int(v) for v in vals]


def check_page_geometry(corpus) -> AnalysisReport:
    """TSL033: every ``serve:`` page-size candidate vs each covered target's
    sublane tiling. A primitive "covers" the targets its definitions name;
    candidates come from ``serve.page_sizes`` (falling back to a lone
    ``serve.page_size``).

    Fused-kernel geometry rides the same code: a primitive declaring
    ``serve.block_ks`` (the block-table attention key-block candidates, e.g.
    ``attention_decode_paged``) walks pool pages as its key grid, so every
    block_k candidate must be compatible — equal or integer-divisible,
    either way round — with every page-size candidate declared by a pager
    primitive (``cache_page_read``) on the same target; otherwise a bench
    winner pairing could leave the kernel with a key block that straddles a
    page boundary and silently degrades to one block per page."""
    rep = AnalysisReport()
    pagers = []      # (name, [page sizes], {covered targets})
    for name in sorted(corpus.primitives):
        prim = corpus.primitives[name]
        sizes = _serve_candidates(prim, "page_sizes", "page_size")
        if not sizes:
            continue
        covered = sorted({d.target_extension for d in prim.definitions})
        pagers.append((name, sizes, set(covered)))
        for tname in covered:
            tgt = corpus.targets.get(tname)
            if tgt is None:
                continue
            sub = tgt.sublanes
            for ps in sizes:
                if ps <= 0 or ps % sub != 0:
                    rep.add("TSL033",
                            f"page-size candidate {ps} is not a positive "
                            f"multiple of {tname}'s sublanes={sub} — every "
                            "page gather relayouts on this target",
                            subject=f"primitive:{name}",
                            location=f"target:{tname}")
    for name in sorted(corpus.primitives):
        prim = corpus.primitives[name]
        bks = _serve_candidates(prim, "block_ks", "block_k")
        if not bks:
            continue
        covered = {d.target_extension for d in prim.definitions}
        # page sizes this primitive can meet per target, with their sources
        for tname in sorted(covered):
            if tname not in corpus.targets:
                continue
            meets: dict[int, list[str]] = {}
            for pname, sizes, ptargets in pagers:
                if tname in ptargets:
                    for ps in sizes:
                        meets.setdefault(ps, []).append(pname)
            for bk in bks:
                for ps, sources in sorted(meets.items()):
                    if bk > 0 and ps > 0 and (ps % bk == 0 or bk % ps == 0):
                        continue
                    rep.add("TSL033",
                            f"block_k candidate {bk} is incompatible with "
                            f"page-size candidate {ps} "
                            f"(from {', '.join(sorted(set(sources)))}) — "
                            "neither divides the other, so a fused key "
                            "block would straddle a page boundary",
                            subject=f"primitive:{name}",
                            location=f"target:{tname}")
    return rep


def lint_kernel_file(path: Path, *, sublanes: int = 8, lanes: int = 128,
                     root: Path | None = None) -> AnalysisReport:
    rep = AnalysisReport()
    rel = str(path.relative_to(root)) if root else path.name
    subject = f"file:{rel}"
    try:
        tree = ast.parse(path.read_text())
    except SyntaxError as e:
        rep.add("TSL040", f"kernel module does not parse: {e.msg} "
                f"(line {e.lineno})", subject=subject)
        return rep
    _check_module(tree, rep, subject=subject,
                  locate=lambda ln: f"line {ln}",
                  sublanes=sublanes, lanes=lanes)
    return rep


def lint_rendered_bodies(bodies: list[RenderedBody]) -> AnalysisReport:
    """Tiling checks over stage-1-rendered UPD definition bodies, each against
    its own target's declared geometry."""
    rep = AnalysisReport()
    for rb in bodies:
        if rb.tree is None:
            continue
        _check_module(rb.tree, rep,
                      subject=f"primitive:{rb.primitive}",
                      locate=lambda ln, rb=rb: f"def[{rb.def_index}] "
                                               f"{rb.target} line {ln}",
                      sublanes=rb.sublanes, lanes=rb.lanes)
    return rep
