"""CLI for the incremental multi-target generation engine.

    python -m repro.core generate --targets cpu_xla,pallas_interpret
    python -m repro.core generate --all --force
    python -m repro.core corpus
    python -m repro.core cache stats
    python -m repro.core cache clear

The paper drives its generator from a ``main.py`` invoked by cmake; this is
the JAX-analogue entry point, plus artifact-cache maintenance.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _add_common(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--upd-path", action="append", default=[],
                    help="extra UPD search path (repeatable)")
    ap.add_argument("--build-root", default=None,
                    help="artifact cache root (default: build/tsl)")


def _cmd_generate(args) -> int:
    from .corpus import load_corpus
    from .library import generate_all

    upd_paths = tuple(args.upd_path)
    corpus = load_corpus(upd_paths)
    if args.all:
        targets = None
    elif args.targets:
        targets = [t for chunk in args.targets for t in chunk.split(",") if t]
    else:
        print("error: pass --targets a,b,... or --all", file=sys.stderr)
        return 2
    out = generate_all(
        targets,
        Path(args.build_root) if args.build_root else None,
        force=args.force,
        corpus=corpus,
        upd_paths=upd_paths,
        only=tuple(args.only) if args.only else None,
        emit_docs=args.docs,
        use_bench_selection=args.bench,
    )
    for name, pkg_dir in out.items():
        print(f"{name}: {pkg_dir}")
    return 0


def _cmd_corpus(args) -> int:
    from .corpus import load_corpus

    corpus = load_corpus(tuple(args.upd_path))
    info = {
        "fingerprint": corpus.fingerprint,
        "targets": sorted(corpus.targets),
        "primitives": len(corpus.primitives),
        "warnings": len(corpus.warnings),
    }
    print(json.dumps(info, indent=1))
    if args.warnings:
        for w in corpus.warnings:
            print(f"  warning: {w}")
    return 0


def _cmd_cache(args) -> int:
    from .cache import ArtifactCache
    from .library import DEFAULT_BUILD_ROOT

    store = ArtifactCache(Path(args.build_root) if args.build_root
                          else DEFAULT_BUILD_ROOT)
    if args.action == "stats":
        print(json.dumps(store.stats(), indent=1))
    else:  # clear
        print(f"removed {store.clear()} cached artifact(s)")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.core",
                                 description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="command", required=True)

    g = sub.add_parser("generate", help="generate libraries for target(s)")
    _add_common(g)
    g.add_argument("--targets", action="append", default=[],
                   help="comma-separated target names (repeatable)")
    g.add_argument("--all", action="store_true",
                   help="every target the corpus defines")
    g.add_argument("--only", action="append", default=[],
                   help="cherry-picked primitive (repeatable; paper 'slim')")
    g.add_argument("--force", action="store_true",
                   help="regenerate even on a cache hit")
    g.add_argument("--bench", action="store_true",
                   help="benchmark-driven adaptive selection (paper §4.2)")
    g.add_argument("--docs", action="store_true", help="emit docs/ markdown")
    g.set_defaults(fn=_cmd_generate)

    c = sub.add_parser("corpus", help="validate + summarize the UPD corpus")
    _add_common(c)
    c.add_argument("--warnings", action="store_true",
                   help="print every corpus warning")
    c.set_defaults(fn=_cmd_corpus)

    k = sub.add_parser("cache", help="artifact-cache maintenance")
    _add_common(k)
    k.add_argument("action", choices=("stats", "clear"))
    k.set_defaults(fn=_cmd_cache)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
