"""Pure-jnp oracle for the Mamba2 SSD recurrence (naive time scan).

State-space duality recurrence (Mamba2, arXiv:2405.21060), scalar-per-head
decay:

    h_t = a_t * h_{t-1} + x_t ⊗ b_t          h: (B, H, P, N)
    y_t = h_t @ c_t                           y: (B, H, P)

with x (B,T,H,P), a (B,T,H) in (0,1), b,c (B,T,N) (shared across heads).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan(x, a, b, c, *, h0=None):
    """Returns (y, h_final): y (B,T,H,P), h (B,H,P,N). f32 internally."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(hprev, inp):
        xt, at, bt, ct = inp            # (B,H,P), (B,H), (B,N), (B,N)
        hnew = at[:, :, None, None] * hprev + xt[..., None] * bt[:, None, None, :]
        yt = jnp.einsum("bhpn,bn->bhp", hnew, ct)
        return hnew, yt

    hT, ys = jax.lax.scan(
        step, h0,
        (xf.transpose(1, 0, 2, 3), af.transpose(1, 0, 2),
         bf.transpose(1, 0, 2), cf.transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2, 3).astype(x.dtype)
    return y, hT


def ssd_decode_step(xt, at, bt, ct, h):
    """One decode step: xt (B,H,P), at (B,H), bt/ct (B,N), h (B,H,P,N)."""
    hf = h.astype(jnp.float32)
    hnew = at.astype(jnp.float32)[:, :, None, None] * hf \
        + xt.astype(jnp.float32)[..., None] * bt.astype(jnp.float32)[:, None, None, :]
    yt = jnp.einsum("bhpn,bn->bhp", hnew, ct.astype(jnp.float32))
    return yt.astype(xt.dtype), hnew
