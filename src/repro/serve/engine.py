"""Per-step continuous-batching serving engine with chunked prefill.

One fixed-shape batched decode state (the slot table) runs ONE unified step
per iteration: every in-flight prefill advances by exactly one fixed-size
chunk (``prefill_chunk`` tokens grafted into its reserved slot's cache by
state surgery), then one jitted decode step runs over every occupied slot.
Long prompts therefore never stall token generation for running slots — a
1000-token prompt is ~(1000/chunk) interleaved chunk steps, with decode
emitting a token for every running request in each of them.

Prompts are padded to UPD-declared length buckets before admission
(``BucketPolicy``), and every bucket is an exact multiple of the chunk size,
so the engine only ever runs shapes it has compiled before: ONE prefill-chunk
shape (plus one first-chunk shape for families with per-request media) and
ONE decode shape — bounded by len(buckets) + 1 per family. Bucket padding is
mathematically exact: pad rows' state updates are skipped (``n_real``
masking in ``Model.prefill_chunk``) and the first token is sampled at the
last REAL row, so chunked+bucketed prefill is token-for-token identical to
whole-prompt prefill.

Requests arrive asynchronously: ``submit()`` (or a preset ``arrival_s`` on
the request) makes a request visible to admission only once the engine clock
reaches its arrival — TTFT and SLA accounting are measured from that arrival,
and shared-step wall time is attributed proportionally to prefill vs decode
tokens so a neighbour's chunk work never inflates a request's decode-t/s.

Speculative decoding (``speculation=SpeculationConfig(...)``) replaces the
per-step decode with a draft/verify loop when the UPD cost channel says it
pays: a drafter proposes up to k tokens per slot, ONE batched ragged verify
step (``Model.verify_step`` over the ``attention_verify`` primitive) scores
every slot's span at its own position, and each slot commits its longest
accepted prefix plus one corrected token. Depth k is per-slot per-step
(``SpeculationPolicy``: acceptance EMA vs drafter + verify roofline cost)
and k = 0 runs the ORIGINAL decode path verbatim — same jitted function,
same sampler, same key draws — so disabled/unprofitable speculation is
token-for-token identical to the plain engine.

Metrics per request: TTFT, prefill_s/decode_s attribution, decode tokens/s
(counting ONLY target-emitted tokens — accepted + corrected, never rejected
drafts), end-to-end latency, SLA hit, bucket; per engine run: real-token
throughput (padded/idle slots never counted), steady-state padded-slot steps
(0 == true continuous batching), TTFT percentiles split by bucket, slot-reuse
counts, the per-step log (chunks run / tokens decoded / emitted), the
admission log, every refusal with its cost-model reason, and — with
speculation on — the ``spec`` block (accepted rate, mean accepted span,
steps per emitted token, split by bucket).
"""

from __future__ import annotations

import time
from dataclasses import asdict, dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.dist import sharding as dist_sharding
from repro.nn.model import build_model

from .paging import PagedConfig, PagedKVStore, prefix_key, selected_page_size
from .scheduler import (BucketPolicy, CostModelAdmission, PagedAdmission,
                        Refusal, Request, Scheduler)
from .slots import PagesExhausted, assert_span_fits, validate_donor
from .spec import (SpeculationConfig, SpeculationPolicy, accept_span,
                   build_drafter, upd_verify_defaults)

# Sharding-invariant RNG: the legacy threefry lowering draws DIFFERENT bits
# when its operand arrives sharded, so a sampled run on a mesh would diverge
# from the 1-device engine at the first categorical draw. The partitionable
# lowering is counter-based per element — same key, same draws, any layout —
# which is what makes the mesh equivalence guarantee hold for sampled
# requests too. Set once at import so meshed and unmeshed engines in one
# process share a single stream (the flag changes sampled streams vs older
# releases; tests only compare within-process).
jax.config.update("jax_threefry_partitionable", True)


@dataclass(frozen=True)
class SamplingConfig:
    """temperature <= 0 -> greedy argmax; top_k 0 -> no truncation."""

    temperature: float = 0.0
    top_k: int = 0


@dataclass
class _PrefillTask:
    """Host-side tracking of one request's chunk schedule.

    The in-flight prefill lives in a batch-1 DONOR state outside the slot
    table, not in the reserved slot itself: the batched decode step runs
    over the FULL table every iteration, and a reserved slot's lane would be
    advanced with a garbage token between chunk steps (clobbering K/V rows
    at its stale position, corrupting recurrent state). The donor is grafted
    into the slot once, at completion."""

    req: Request
    slot: int
    padded: np.ndarray          # (1, bucket) prompt padded to its bucket
    n_chunks: int
    donor: object               # batch-1 decode-state pytree being filled
    chunk_idx: int = 0
    fill: int = 0               # REAL rows in the donor's cache (incl. prefix)
    first_logits: np.ndarray | None = None   # logits at the last real row
    prefill_s: float = 0.0
    # paged mode (slot == -1: no lane is reserved; the request activates
    # into a free lane at completion or parks resident in pages)
    share_key: str | None = None    # prefix-store content address
    share_rows: int = 0             # aligned share-boundary cache rows
    publish: bool = False           # miss: this task publishes the prefix
    boundary_tail: dict | None = None   # tail snapshot AT the boundary


class ServeEngine:
    def __init__(self, cfg, *, batch: int, max_len: int,
                 sampling: SamplingConfig | None = None, seed: int = 0,
                 enc_len: int | None = None, admission: bool = True,
                 prefill_chunk: int | None = None,
                 buckets: tuple[int, ...] | None = None,
                 speculation: SpeculationConfig | None = None,
                 paged: PagedConfig | None = None,
                 mesh=None):
        if cfg.family == "audio" and enc_len is None:
            raise ValueError("audio family: pass enc_len (the fixed encoder "
                             "length every request's frames are sized to)")
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.enc_len = enc_len
        self.sampling = sampling or SamplingConfig()
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        # -- mesh-sharded serving (repro.dist rules over a jax mesh) ---------
        # params shard row/col-TP with the output-projection flip; slot-table
        # and page-pool state shard batch-on-data / sequence-on-model. Every
        # jitted step pins its returned state to those SAME rules
        # (with_sharding_constraint), so inputs and outputs agree and
        # steady-state steps run with zero resharding — asserted by the
        # ``reshard_events`` counter the report carries.
        self.mesh = mesh
        self._reshard_events = 0
        if mesh is not None:
            self.params = jax.device_put(
                self.params, dist_sharding.param_shardings(mesh, self.params))
        # the slot cache is filled to prompt_len + decode_prefix (vlm vision
        # rows), and decode must write AFTER it
        self._prefix = cfg.decode_prefix
        # chunk size + admissible padded prompt lengths are UPD data (the
        # serve: block on attention_prefill_chunk); buckets that cannot fit
        # the slot table (prefix + bucket + 1 gen token) are dropped, and an
        # engine with none left falls back to the largest chunk multiple
        # that fits, so tiny test tables still serve
        base = BucketPolicy.from_upd(chunk=prefill_chunk, buckets=buckets)
        chunk = base.chunk
        fit = tuple(b for b in base.buckets
                    if self._prefix + b + 1 <= max_len)
        if not fit:
            largest = ((max_len - self._prefix - 1) // chunk) * chunk
            if largest < chunk:
                raise ValueError(
                    f"max_len={max_len} leaves no room for a single "
                    f"prefill chunk of {chunk}")
            fit = (largest,)
        self.policy = BucketPolicy(fit, chunk)
        # -- speculative decoding depth (needed before the paged store: the
        # slot table carries k_max scratch rows) -----------------------------
        # The verify span writes k_max+1 cache rows at each slot's fill; a
        # slot whose window is smaller than the step's global K would have
        # rows from NEIGHBOURS' depth written past its own budget, so the
        # slot table (and every donor) carries k_max scratch rows of
        # headroom beyond max_len — dynamic_update_slice clamping near the
        # boundary would otherwise silently corrupt the last real rows.
        self.spec = speculation
        self._k_max = 0
        if speculation is not None:
            self._k_max = speculation.k_max if speculation.k_max is not None \
                else upd_verify_defaults()["k_max"]
        self._state_len = max_len + self._k_max
        # family-declared per-leaf axis contracts drive the state sharding
        # rules: state_page_axes names the TRUE token axis of each leaf (None
        # = fixed-size recurrent tail — sharding one of its feature axes on
        # ``model`` would reassociate the reductions that consume it and
        # break token-for-token equivalence), state_batch_axes the request
        # axis. Families without the contracts fall back to the shape
        # heuristic in dist.sharding.
        self._state_token_axes = None
        self._state_batch_axes = None
        if mesh is not None and self.model.state_page_axes is not None:
            shapes = jax.eval_shape(
                lambda: self.model.init_decode_state(
                    1, self._state_len, enc_len=self.enc_len))
            if isinstance(shapes, dict):
                self._state_token_axes = self.model.state_page_axes(shapes)
                if self.model.state_batch_axes is not None:
                    self._state_batch_axes = self.model.state_batch_axes(shapes)
        # -- paged slot memory (block-table residency under the lanes) -------
        self.paged = paged
        self._store: PagedKVStore | None = None
        self._seed = seed
        self._max_inflight = 0
        self._parked: dict[str, dict] = {}      # rid -> resume info (FIFO)
        self._resumed: dict[str, dict] = {}     # rid -> preemption stash
        self._inflight_keys: dict[str, str] = {}  # share key -> publisher rid
        self._act_stamp: dict[int, int] = {}    # slot -> activation seq
        self._act_seq = 0
        self._preempt_count = 0
        if paged is not None:
            if self.model.state_page_axes is None:
                raise ValueError(f"family {cfg.family!r} does not declare "
                                 "state_page_axes (paged serving needs the "
                                 "per-leaf token-axis contract)")
            donor_shapes = jax.eval_shape(
                lambda: self.model.init_decode_state(
                    1, self._state_len, enc_len=self.enc_len))
            page_axes = self.model.state_page_axes(donor_shapes)
            psize = paged.page_size or selected_page_size()
            if paged.hbm_budget_bytes is not None:
                self._store = PagedKVStore(
                    donor_shapes, page_axes, page_size=psize,
                    hbm_budget_bytes=paged.hbm_budget_bytes, int8=paged.int8,
                    fused=paged.fused)
            else:
                # default budget: pages for 2x the lane count at worst-case
                # length — out of the box, paged strictly dominates the
                # contiguous table and never preempts a lane-bound load
                probe = PagedKVStore(donor_shapes, page_axes,
                                     page_size=psize, n_pages=1,
                                     int8=paged.int8)
                self._store = PagedKVStore(
                    donor_shapes, page_axes, page_size=psize,
                    n_pages=2 * batch * max(probe.pages_for_rows(max_len), 1),
                    int8=paged.int8, fused=paged.fused)
            self._max_inflight = paged.max_inflight_prefills or 2 * batch
            self._page_axes = page_axes
        # FUSED paged decode: KV-family slots decode/verify DIRECTLY against
        # the block-table page pools (attention_decode_paged /
        # attention_verify_paged) — no page->lane gather on the steady-state
        # path. The store downgrades fused for families with no paged leaves
        # (rwkv), and a family without the paged step contract falls back to
        # lane activation the same way.
        self._fused = bool(self._store is not None and self._store.fused
                           and self.model.decode_step_paged is not None)
        if self._store is not None and self._store.fused and not self._fused:
            # paged leaves but no fused contract: rebuild flat (lane mode)
            self._store = PagedKVStore(
                donor_shapes, page_axes, page_size=self._store.page,
                n_pages=self._store.n_pages, int8=paged.int8)
        self._table_width = 0
        if self._fused:
            self._table_width = -(-self._state_len // self._store.page)
        # page pools shard like the slot state they mirror: the token axis
        # was split into (n_pages, page), so the PAGE axis takes the model
        # entry the sequence dim would have (divisibility-guarded), and the
        # engine re-pins after any host-path pool mutation so fused steps
        # always see the same input shardings they compiled against
        self._pool_shardings: dict | None = None
        if mesh is not None and self._store is not None:
            self._pool_shardings = self._pool_sharding_rules()
            for n in self._store.pools:
                self._store.pools[n] = jax.device_put(
                    self._store.pools[n], self._pool_shardings[n])
            for n in self._store.scale_pools:
                key = f"{n}__scale"
                self._store.scale_pools[n] = jax.device_put(
                    self._store.scale_pools[n], self._pool_shardings[key])
        # fused-path counters for report["paged"]
        self._lane_activations = 0      # full page->lane gathers (fallback)
        self._tail_restores = 0         # fused activations (tails only)
        self._gather_bytes_eliminated = 0
        if not admission:
            self.cost_model = None
        elif self._store is not None:
            self.cost_model = PagedAdmission(cfg, batch, max_len,
                                             budget=self._store,
                                             enc_len=enc_len,
                                             policy=self.policy, mesh=mesh)
        else:
            self.cost_model = CostModelAdmission(cfg, batch, max_len,
                                                 enc_len=enc_len,
                                                 policy=self.policy,
                                                 mesh=mesh)
        # -- speculative decoding (draft/verify over the slot table) ---------
        self._drafter = None
        self._spec_policy = None
        self._verify = None
        self._commit = None

        # jit wrappers: on a mesh, every compiled step pins its returned
        # state (and pools) to the dist.sharding rules — inputs already
        # carry them, so outputs match inputs and the donated buffers are
        # reused without a single resharding copy in steady state
        def _ls(fn):
            """(logits, state)-returning step."""
            if mesh is None:
                return fn

            def wrapped(params, state, *args):
                logits, st = fn(params, state, *args)
                return logits, self._pin_state(st)
            return wrapped

        def _st(fn):
            """state-returning step (insert/reset/commit)."""
            if mesh is None:
                return fn

            def wrapped(*args):
                return self._pin_state(fn(*args))
            return wrapped

        def _lsp(fn):
            """(logits, state, pools)-returning fused paged step."""
            if mesh is None:
                return fn

            def wrapped(params, state, pools, *args):
                logits, st, pl = fn(params, state, pools, *args)
                return logits, self._pin_state(st), self._pin_pools(pl)
            return wrapped

        def _sp(fn):
            """(state, pools)-returning fused paged commit."""
            if mesh is None:
                return fn

            def wrapped(params, state, pools, *args):
                st, pl = fn(params, state, pools, *args)
                return self._pin_state(st), self._pin_pools(pl)
            return wrapped

        if speculation is not None:
            self._drafter = build_drafter(speculation, cfg, batch=batch,
                                          state_len=self._state_len,
                                          seed=seed + 2)
            pricing = self.cost_model or CostModelAdmission(
                cfg, batch, max_len, enc_len=enc_len, policy=self.policy,
                mesh=mesh)
            if self.cost_model is not None:
                self.cost_model.spec_k = self._k_max
            self._spec_policy = SpeculationPolicy(
                batch, self._k_max, pricing, speculation,
                drafter_cost_s=self._drafter.cost_per_token_s())
            self._verify = jax.jit(_ls(self.model.verify_step),
                                   donate_argnums=(1,))
            if self.model.verify_commit is not None:
                self._commit = jax.jit(_st(self.model.verify_commit),
                                       donate_argnums=(1,))
        # donate the incoming state: it is dead after every call, and without
        # donation each step/insert/reset copies the full multi-layer cache
        self._decode = jax.jit(_ls(self.model.decode_step), donate_argnums=(1,))
        # fused paged steps: the tail state AND the pool dict are donated —
        # the pools are updated in place on device and re-adopted by the
        # store after every call (set_device_pools)
        self._decode_paged = None
        self._verify_paged = None
        self._commit_paged = None
        if self._fused:
            self._decode_paged = jax.jit(_lsp(self.model.decode_step_paged),
                                         donate_argnums=(1, 2))
            if speculation is not None:
                self._verify_paged = jax.jit(_lsp(self.model.verify_step_paged),
                                             donate_argnums=(1, 2))
                if self.model.verify_commit_paged is not None:
                    self._commit_paged = jax.jit(
                        _sp(self.model.verify_commit_paged),
                        donate_argnums=(1, 2))
        self._insert = jax.jit(_st(self.model.insert_slot), donate_argnums=(0,))
        self._reset = jax.jit(_st(self.model.reset_slot), donate_argnums=(0,))
        self._chunk = jax.jit(self._chunk_fn, donate_argnums=(1,))
        self._sample = self._build_sampler()
        self._key = jax.random.PRNGKey(seed + 1)
        self._inbox: list[Request] = []

    # -- helpers --------------------------------------------------------------

    def _chunk_fn(self, params, donor, tokens, pos, n_real, embeds=None):
        """One continuation-prefill chunk into the batch-1 donor state;
        returns the logits row at the chunk's last REAL token and the
        updated donor. ``pos``/``n_real`` are traced — one compiled shape
        covers every fill level and padding amount."""
        logits, donor = self.model.prefill_chunk(
            params, donor, tokens, pos, pos, n_real=n_real, embeds=embeds)
        # vlm/audio first chunk prepends prefix rows: index relative to them
        prefix = logits.shape[1] - tokens.shape[1]
        idx = prefix + jnp.maximum(n_real, 1) - 1
        last = jnp.take(logits, idx, axis=1)                # (1, V)
        return last, donor

    def _build_sampler(self):
        """Per-slot-temperature sampler: ``temps`` (B,) lets greedy and
        sampled requests coexist in one batched step (and in one verify
        span, where logits are (B, SV, V) and every span row samples at its
        slot's temperature). temp <= 0 rows take the argmax; for a uniform
        temperature this reduces exactly to the scalar sampler (same key,
        same categorical draw)."""
        top_k = self.sampling.top_k
        vocab = self.cfg.vocab

        def sample(logits, key, temps):
            # the lm head is padded_vocab wide: never emit a padding id
            keep = jnp.arange(logits.shape[-1]) < vocab
            masked = jnp.where(keep, logits, jnp.full_like(logits, -1e30))
            greedy = jnp.argmax(masked, axis=-1)
            t = temps.reshape((logits.shape[0],) + (1,) * (logits.ndim - 1))
            scaled = masked.astype(jnp.float32) / jnp.maximum(t, 1e-6)
            if top_k:
                kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
                scaled = jnp.where(scaled < kth, jnp.float32(-1e30), scaled)
            drawn = jax.random.categorical(key, scaled, axis=-1)
            use_draw = temps.reshape(
                (logits.shape[0],) + (1,) * (logits.ndim - 2)) > 0
            return jnp.where(use_draw, drawn, greedy)

        return jax.jit(sample)

    def _slot_temperature(self, req: Request) -> float:
        return self.sampling.temperature if req.temperature is None \
            else float(req.temperature)

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- mesh helpers ---------------------------------------------------------

    def _state_shardings(self, state):
        """Rule shardings for a slot-table state pytree, steered by the
        family's declared token/batch axis contracts when available."""
        return dist_sharding.state_shardings(
            self.mesh, state, token_axes=self._state_token_axes,
            batch_axes=self._state_batch_axes)

    def _pin_state(self, state):
        """Constrain every state leaf to its ``dist.sharding`` rule — used
        INSIDE the jitted steps so compiled outputs carry exactly the
        shardings the inputs arrived with (the zero-resharding invariant).
        Identity off-mesh."""
        if self.mesh is None:
            return state
        shards = self._state_shardings(state)
        return jax.tree.map(jax.lax.with_sharding_constraint, state, shards)

    def _pin_pools(self, pools: dict) -> dict:
        if self._pool_shardings is None:
            return pools
        return {n: jax.lax.with_sharding_constraint(a, self._pool_shardings[n])
                for n, a in pools.items()}

    def _pool_sharding_rules(self) -> dict:
        """NamedSharding per pool leaf (scale pools as ``{leaf}__scale``):
        the page axis — the split token axis — takes the ``model`` entry the
        sequence dim carries in the slot table, divisibility-guarded."""
        st = self._store
        tp = dist_sharding.tp_size(self.mesh)
        out = {}
        for name, (ax, row_shape, _dt) in st.paged.items():
            page_axis = ax if st.fused else 0
            ndim = len(row_shape) + (2 if st.fused else 1)
            n_along = st.n_pages if st.fused else st.n_pages * st.page
            entries = [None] * ndim
            if tp > 1 and n_along % tp == 0 and n_along >= tp:
                entries[page_axis] = "model"
            spec = PartitionSpec(*entries)
            out[name] = NamedSharding(self.mesh, spec)
            if name in st.scale_pools:
                out[f"{name}__scale"] = NamedSharding(self.mesh, spec)
        return out

    def _sharded_device_pools(self) -> dict:
        """Device pools re-pinned to their rule shardings: host-path writes
        (prefill commit, CoW, spill/rehydrate) run eagerly and may leave a
        pool differently laid out; a no-op when shardings already match, so
        the steady-state decode path never copies."""
        pools = self._store.device_pools()
        if self._pool_shardings is None:
            return pools
        return {n: a if a.sharding == self._pool_shardings[n]
                else jax.device_put(a, self._pool_shardings[n])
                for n, a in pools.items()}

    def _new_donor(self):
        """Fresh batch-1 donor, mesh-placed: created with the SAME rule
        shardings the chunk jit's donated output carries, so every chunk
        call compiles once and reuses the donor buffers."""
        donor = self.model.init_decode_state(1, self._state_len,
                                             enc_len=self.enc_len)
        if self.mesh is not None:
            donor = jax.device_put(donor, self._state_shardings(donor))
        return donor

    def _check_steady_sharding(self, state, pools: dict | None = None):
        """Post-step audit (mesh mode): every state/pool leaf must still
        carry its rule sharding. Any drift is a resharding event — the
        counter lands in report["mesh"]["reshard_events"] and tests assert
        it stays 0."""
        if self.mesh is None:
            return
        expected = self._state_shardings(state)
        leaves = list(zip(jax.tree.leaves(state), jax.tree.leaves(expected)))
        if pools is not None and self._pool_shardings is not None:
            leaves += [(a, self._pool_shardings[n])
                       for n, a in pools.items()]
        for got, want in leaves:
            if not got.sharding.is_equivalent_to(want, got.ndim):
                self._reshard_events += 1

    def _init_state(self):
        # _state_len = max_len + k_max: verify-span slab headroom (see
        # __init__) — admission and the overrun guards still cap real fill
        # at max_len, the scratch rows only ever hold rejected drafts
        state = self.model.init_decode_state(self.batch, self._state_len,
                                             enc_len=self.enc_len)
        if self._fused:
            # fused mode keeps only the TAIL leaves in the slot table: the
            # paged leaves live exclusively in the store's pools and every
            # decode/verify reads them through the block table
            state = {n: state[n] for n, ax in self._page_axes.items()
                     if ax is None}
        if self.mesh is not None:
            state = jax.device_put(state, self._state_shardings(state))
        return state

    def _donor_tails(self, donor: dict) -> dict:
        return {n: donor[n] for n in self._store.tail_leaves}

    def _tails_template(self) -> dict:
        """Zeroed tails-only donor (size-1 slot axis) for fused activation —
        load_donor restores the tail snapshot into it and, lacking the paged
        leaves, skips the page gather entirely."""
        return {n: jnp.zeros(shape, dt)
                for n, (shape, dt) in self._store.tail_leaves.items()}

    def _build_tables(self, sched, active) -> jnp.ndarray:
        """(B, P) int32 block table for this step: active slots' page lists
        (scratch-padded past coverage), all-scratch rows for idle slots —
        every entry is a valid page id, the fused kernels' index maps fetch
        unconditionally."""
        tabs = np.full((self.batch, self._table_width),
                       self._store.scratch_page, np.int32)
        for slot in active:
            rid = sched.slots[slot].request.rid
            tabs[slot] = self._store.table_row(rid, self._table_width)
        return jnp.asarray(tabs)

    def _first_chunk_embeds(self, req: Request):
        """Per-request media for the FIRST chunk: vlm vision prefix rows /
        audio encoder frames (zeros when the request carries none)."""
        cfg = self.cfg
        if cfg.family == "vlm":
            if req.embeds is not None:
                return jnp.asarray(req.embeds, cfg.dtype)[None]
            return jnp.zeros((1, cfg.vision_prefix, cfg.d_model), cfg.dtype)
        if cfg.family == "audio":
            if req.embeds is not None:
                return jnp.asarray(req.embeds, cfg.dtype)[None]
            return jnp.zeros((1, self.enc_len, cfg.d_model), cfg.dtype)
        return None

    # -- paged serving helpers ------------------------------------------------

    def _share_plan(self, req: Request) -> tuple[str | None, int]:
        """(content key, boundary rows) for the shareable prefix of ``req``,
        or (None, 0) when nothing aligned is shareable. The boundary is the
        largest chunk-aligned token count <= the caller's shared_prefix_len
        hint (default: the whole prompt minus its last token — the first
        token's logits must come from a re-run chunk) whose ROW count (media
        prefix + tokens) is page-aligned: only whole pages are shared."""
        if self._store is None or not self.paged.prefix_sharing:
            return None, 0
        cap = req.prompt_len - 1 if req.shared_prefix_len is None \
            else min(int(req.shared_prefix_len), req.prompt_len - 1)
        chunk = self.policy.chunk
        t = (cap // chunk) * chunk
        if self._store.paged:
            while t >= chunk and (self._prefix + t) % self._store.page:
                t -= chunk
        if t < chunk:
            return None, 0
        toks = np.asarray(req.tokens, np.int64)[:t]
        key = prefix_key(arch=self.cfg.name, page_size=self._store.page,
                         int8=self._store.int8, seed=self._seed,
                         prefix_rows=self._prefix, tokens=toks,
                         embeds=req.embeds)
        return key, self._prefix + t

    def _reserve_paged(self, sched: Scheduler, tasks: list, now, step: int):
        """Paged reservation: admission is a PAGE decision, not a lane one —
        admit up to max_inflight concurrent prefills, attach each to the
        store (prompt pages now, shared prefix retained on a hit), and
        fast-forward the chunk schedule past shared rows. A prompt whose
        prefix is being prefilled by an in-flight publisher DEFERS until the
        entry is published, which is what makes prefill-once exact: the
        followers hit the store instead of racing the publisher."""
        chunk = self.policy.chunk
        while len(tasks) < self._max_inflight:
            req = sched.next_admissible(now())
            if req is None:
                break
            share_key, share_rows = self._share_plan(req)
            if share_key is not None and share_key in self._inflight_keys:
                sched.requeue_front(req)
                break
            bucket = req.bucket or self.policy.assign(req.prompt_len)
            if not bucket:
                bucket = BucketPolicy.round_up(req.prompt_len, chunk)
            req.bucket = bucket
            try:
                shared = self._store.attach(
                    req.rid, prompt_rows=self._prefix + req.prompt_len,
                    share_key=share_key)
            except PagesExhausted:
                # admission saw enough pages, the attach lost the race
                # (tail rounding / concurrent attaches): transient, retry
                sched.requeue_front(req)
                break
            padded = np.zeros((1, bucket), np.int64)
            padded[0, :req.prompt_len] = np.asarray(req.tokens, np.int64)
            task = _PrefillTask(
                req=req, slot=-1, padded=padded, n_chunks=bucket // chunk,
                donor=self._new_donor(),
                share_key=share_key, share_rows=share_rows)
            if shared:
                # prefix hit: seed the donor from the shared pages (+ the
                # boundary tail snapshot) and skip the chunks they cover —
                # the shared prompt rows are never prefilled again
                task.donor = self._store.load_donor(req.rid, task.donor)
                task.fill = shared
                task.chunk_idx = (shared - self._prefix) // chunk
            elif share_key is not None:
                task.publish = True
                self._inflight_keys[share_key] = req.rid
            sched.reserve_unplaced(req, step)
            tasks.append(task)

    def _activate_parked(self, sched: Scheduler, state, pending_host,
                         pos_host, temps_host, histories):
        """Drain parked (resident, lane-less) requests into free lanes,
        FIFO: gather the request's pages back into a fresh donor
        (cache_page_read; int8 pages dequantize here), graft it, and resume
        decoding at its committed fill."""
        for slot in sched.free_slots():
            if not self._parked:
                break
            rid = next(iter(self._parked))
            info = self._parked.pop(rid)
            self._store.pin(rid)        # hot again: rehydrate + no spilling
            if self._fused:
                # tails-only restore: the paged leaves stay in the pools and
                # the next step reads them through the block table — the
                # page->lane gather the lane path would run here is the
                # bytes we count as eliminated
                donor = self._store.load_donor(rid, self._tails_template())
                self._tail_restores += 1
                self._gather_bytes_eliminated += \
                    self._store.requests[rid].fill * self._store.fp_row_bytes
            else:
                donor = self._store.load_donor(rid, self._new_donor())
                self._lane_activations += 1
            validate_donor(state, donor, self.model.state_batch_axes(state))
            state = self._insert(state, donor, slot)
            sched.place_parked(rid, slot)
            temps_host[slot] = info["temp"]
            pending_host[slot] = info["pending"]
            pos_host[slot] = info["fill"]
            histories[slot] = info["history"]
            self._act_seq += 1
            self._act_stamp[slot] = self._act_seq
            if self._spec_policy is not None:
                self._spec_policy.reset(slot)
            if self._drafter is not None:
                self._drafter.on_graft(rid, slot, histories[slot])
        return state

    def _preempt_slot(self, slot: int, sched: Scheduler, state, histories):
        """Page exhaustion: evict the latest-activated decoding request from
        its lane, free its pages, and requeue a CONTINUATION at the queue
        head — prompt = everything the model has consumed, resume_token =
        the emitted-but-unconsumed pending token. Re-prefilling those rows
        reproduces the evicted cache exactly (chunked prefill is
        token-for-token identical to decode), so preemption is lossless."""
        req, m = sched.preempt(slot)
        rid = req.rid
        hist = histories.pop(slot)
        prev = self._resumed.get(rid)
        self._resumed[rid] = {
            # original identity survives any number of preemptions
            "prompt_len": prev["prompt_len"] if prev else m.prompt_len,
            "bucket": prev["bucket"] if prev else m.bucket,
            "admitted_at_step": (prev["admitted_at_step"] if prev
                                 else m.admitted_at_step),
            "gen_len": m.gen_len,
            "ttft_s": m.ttft_s,
            "tokens_out": m.tokens_out,
            "prefill_s": m.prefill_s,
            "decode_s": m.decode_s,
            "preemptions": m.preemptions + 1,
            "spec_proposed": m.spec_proposed,
            "spec_accepted": m.spec_accepted,
            "verify_rounds": m.verify_rounds,
        }
        self._preempt_count += 1
        sched.requeue_front(Request(
            rid=rid, tokens=np.asarray(hist[:-1], np.int64),
            gen_len=m.gen_len, sla_s=req.sla_s, embeds=req.embeds,
            arrival_s=req.arrival_s, temperature=req.temperature,
            shared_prefix_len=req.shared_prefix_len,
            resume_token=int(hist[-1])))
        self._store.free(rid)
        self._act_stamp.pop(slot, None)
        state = self._reset(state, slot)
        if self._drafter is not None:
            self._drafter.on_finish(slot)
        return state

    def _grow_or_preempt(self, active, k_vec, sched, state, pos_host,
                         histories):
        """Before phase 2: every decoding slot's pages must cover the rows
        this step may commit (pos + depth + 1, capped at max_len — verify
        scratch rows beyond max_len only ever hold rejected drafts and are
        never committed). On exhaustion, preempt the LATEST-activated slot
        (LIFO: the one that has sunk the least decode work since
        activation) until the grow fits — possibly the growing slot
        itself."""
        active = list(active)
        for slot in list(active):
            if slot not in active:
                continue
            rid = sched.slots[slot].request.rid
            need = min(int(pos_host[slot]) + int(k_vec[slot]) + 1,
                       self.max_len)
            while True:
                try:
                    self._store.grow(rid, need)
                    break
                except PagesExhausted:
                    victims = [s for s in active if s != slot] or [slot]
                    victim = max(victims,
                                 key=lambda s: self._act_stamp.get(s, -1))
                    state = self._preempt_slot(victim, sched, state,
                                               histories)
                    active.remove(victim)
                    k_vec[victim] = 0
                    if victim == slot:
                        break
        return active, state

    def _complete_paged(self, task: _PrefillTask, sched: Scheduler, state,
                        now, outputs, histories, pending_host, pos_host,
                        temps_host):
        """Prefill completion in paged mode: commit the donor's rows past
        the shared boundary into pages (cache_page_write; int8 quantizes
        here), publish the prefix on a miss, sample the first token (or
        resume a preemption's pending token), then activate into a free
        lane — or PARK: the request stays resident in pages only, counted
        by resident_requests, and activates when a lane frees. Returns
        (state, first-tokens emitted: 0 for a resumed continuation)."""
        req, rid = task.req, task.req.rid
        tail = self._store.snapshot_tail(task.donor) \
            if self._store.tail_leaves else None
        self._store.store_donor(rid, task.donor, fill=task.fill, tail=tail)
        if task.publish:
            self._store.publish_prefix(rid, task.share_key,
                                       n_rows=task.share_rows,
                                       tail=task.boundary_tail)
            self._inflight_keys.pop(task.share_key, None)
        m = sched.unplaced_metrics(rid)
        stash = self._resumed.pop(rid, None)
        if stash is not None:
            for name, val in stash.items():
                setattr(m, name, val)
        m.prefill_s += task.prefill_s
        temp = self._slot_temperature(req)
        gen_inc = 0
        if req.resume_token is not None:
            first = int(req.resume_token)
        else:
            first = int(np.asarray(self._sample(
                jnp.asarray(task.first_logits), self._next_key(),
                jnp.asarray([temp], np.float32)))[0])
            outputs[rid] = [first]
            gen_inc = 1
            sched.first_token_unplaced(rid, now())
        history = [int(t) for t in np.asarray(req.tokens)] + [first]
        if m.tokens_out >= m.gen_len:
            # gen_len == 1: finished without ever taking a lane
            sched.finish_unplaced(rid, now())
            self._store.free(rid)
            return state, gen_inc
        free = sched.free_slots()
        if free:
            slot = free[0]
            donor = self._donor_tails(task.donor) if self._fused \
                else task.donor
            validate_donor(state, donor,
                           self.model.state_batch_axes(state))
            state = self._insert(state, donor, slot)
            sched.place_parked(rid, slot)
            temps_host[slot] = temp
            pending_host[slot] = first
            pos_host[slot] = task.fill
            histories[slot] = history
            self._act_seq += 1
            self._act_stamp[slot] = self._act_seq
            if self._spec_policy is not None:
                self._spec_policy.reset(slot)
            if self._drafter is not None:
                self._drafter.on_graft(rid, slot, history)
        else:
            self._parked[rid] = {"pending": first, "fill": task.fill,
                                 "temp": temp, "history": history}
            self._store.unpin(rid)      # parked: cold, host-spillable
        return state, gen_inc

    def jit_cache_sizes(self) -> dict:
        """Compiled-entry counts of the engine's jitted device functions —
        the probe behind the "never runs a shape it hasn't compiled" claim
        (bounded by len(buckets) + 1 per family). ``_cache_size`` is a
        private jax API: report -1 rather than dying if it moves."""

        def sz(fn) -> int:
            try:
                return int(fn._cache_size())
            except Exception:
                return -1

        sizes = {"prefill_chunk": sz(self._chunk), "decode": sz(self._decode)}
        if self._verify is not None:
            sizes["verify"] = sz(self._verify)
        if self._commit is not None:
            sizes["commit"] = sz(self._commit)
        return sizes

    # -- async ingestion ------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Hand a request to a RUNNING engine loop: it is drained into the
        scheduler at the next step boundary and stamped/gated by its
        arrival_s like any trace-driven request."""
        self._inbox.append(req)

    # -- the serving loop -----------------------------------------------------

    def run(self, requests: list[Request]) -> dict:
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError("duplicate request rids (outputs and metrics "
                             "are keyed by rid)")
        bad = [r.rid for r in requests if r.gen_len < 1]
        if bad:
            raise ValueError(f"gen_len must be >= 1 (requests {bad}); the "
                             "first token always comes from prefill")
        sched = Scheduler(self.batch, admission=self.cost_model)
        # paged run-state (parking, preemption stashes, publisher locks)
        self._parked, self._resumed, self._inflight_keys = {}, {}, {}
        self._act_stamp, self._act_seq, self._preempt_count = {}, 0, 0
        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0  # noqa: E731
        for r in requests:
            sched.submit(r, now())

        state = self._init_state()
        # host mirrors of per-slot decode-loop state: the pending token
        # (emitted but not yet consumed by the model), the cache fill, the
        # sampling temperature, and — for the drafter — the committed token
        # history (prompt + emitted)
        pending_host = np.zeros(self.batch, np.int64)
        pos_host = np.zeros(self.batch, np.int64)
        temps_host = np.full(self.batch, self.sampling.temperature,
                             np.float32)
        histories: dict[int, list[int]] = {}
        outputs: dict[str, list[int]] = {}
        tasks: list[_PrefillTask] = []
        step_log: list[dict] = []
        step = 0
        padded_steady = 0
        generated = 0
        prefill_tokens_total = 0
        decode_emitted = 0          # tokens emitted by phase-2 steps
        slot_steps = 0              # per-slot phase-2 participations
        decode_steps = 0            # plain (k=0) decode steps
        verify_steps = 0            # speculative verify rounds
        spec_proposed_total = 0
        spec_accepted_total = 0
        spec_slot_rounds = 0        # (slot, round) pairs that speculated
        chunk = self.policy.chunk

        seen_rids = set(rids)
        while sched.has_work() or tasks or self._inbox:
            t_step0 = time.perf_counter()
            # pop-drain (never iterate-then-clear): a concurrent submit()
            # landing between the two would be silently dropped
            while self._inbox:
                r = self._inbox.pop(0)
                # streamed requests get the same validation as run()'s list,
                # but as recorded refusals — one bad submit must not kill
                # the loop for everyone already being served
                if r.rid in seen_rids:
                    sched.refused.append(Refusal(
                        r.rid, "invalid: duplicate rid in this run"))
                    continue
                if r.gen_len < 1:
                    sched.refused.append(Refusal(
                        r.rid, "invalid: gen_len must be >= 1 (the first "
                               "token always comes from prefill)"))
                    continue
                seen_rids.add(r.rid)
                sched.submit(r, now())
            sched.release(now())

            # -- reservation: every free slot starts a chunk schedule --------
            # (paged mode: activation + admission are PAGE decisions — drain
            # parked requests into freed lanes, then admit lane-less)
            if self._store is not None:
                state = self._activate_parked(sched, state, pending_host,
                                              pos_host, temps_host,
                                              histories)
                self._reserve_paged(sched, tasks, now, step)
            else:
                while True:
                    free = sched.free_slots()
                    if not free:
                        break
                    req = sched.next_admissible(now())
                    if req is None:
                        break
                    bucket = req.bucket or self.policy.assign(req.prompt_len)
                    if not bucket:
                        # admission off + prompt beyond the largest bucket:
                        # still cover the whole prompt in whole chunks (the
                        # max_len overrun guard stays the only hard stop)
                        bucket = BucketPolicy.round_up(req.prompt_len, chunk)
                    req.bucket = bucket
                    padded = np.zeros((1, bucket), np.int64)
                    padded[0, :req.prompt_len] = np.asarray(req.tokens,
                                                            np.int64)
                    tasks.append(_PrefillTask(
                        req=req, slot=free[0], padded=padded,
                        n_chunks=bucket // chunk,
                        donor=self._new_donor()))
                    sched.reserve(free[0], req, step)

            # -- unified step, phase 1: one chunk per in-flight prefill ------
            ran: list[_PrefillTask] = []
            for task in tasks:
                c0 = task.chunk_idx * chunk
                seg = jnp.asarray(task.padded[:, c0:c0 + chunk], jnp.int32)
                n_real = max(0, min(task.req.prompt_len - c0, chunk))
                embeds = self._first_chunk_embeds(task.req) \
                    if task.chunk_idx == 0 else None
                writes = chunk + (self._prefix if task.chunk_idx == 0 else 0)
                if task.fill + writes > self.max_len:
                    raise RuntimeError(
                        f"prefill chunk for {task.req.rid!r} would overrun "
                        f"max_len={self.max_len} (admission off?)")
                last, task.donor = self._chunk(
                    self.params, task.donor, seg,
                    jnp.int32(task.fill), jnp.int32(n_real), embeds)
                if self._drafter is not None:
                    # a draft-model drafter mirrors the chunk schedule into
                    # its own donor (no-op for the n-gram drafter)
                    self._drafter.on_chunk(task.req.rid,
                                           task.padded[:, c0:c0 + chunk],
                                           n_real)
                task.chunk_idx += 1
                ran.append(task)
                if task.chunk_idx == 1:
                    task.fill += self._prefix       # vlm vision rows
                if n_real:
                    task.fill += n_real
                    task.first_logits = np.asarray(last)    # syncs the chunk
                if (task.publish and task.boundary_tail is None
                        and self._store is not None
                        and self._store.tail_leaves
                        and task.fill >= task.share_rows):
                    # recurrent-tail families: the prefix entry must restore
                    # the state AT the boundary, so snapshot it the moment
                    # the fill crosses (boundary is chunk-aligned — the
                    # crossing is exact)
                    task.boundary_tail = self._store.snapshot_tail(task.donor)
            chunk_tokens = len(ran) * chunk
            prefill_tokens_total += chunk_tokens

            active = sched.active_slots()
            if sched.queue and self._store is None:
                # released queue still has work: every free, unreserved slot
                # this step is waste. With per-step admission this is 0 by
                # construction — the counter is a tripwire so any future
                # scheduling policy that delays admission surfaces its cost
                # here instead of silently regressing
                padded_steady += self.batch - len(active) - len(tasks)

            # -- phase 2: one decode OR verify step over every occupied slot -
            emitted_this_step = 0
            # per-slot speculation depth, priced per step: clipped to the
            # slot's remaining generation budget, 0 when the cost channel
            # says drafting doesn't pay (or speculation is off)
            k_vec = np.zeros(self.batch, np.int64)
            if active and self._spec_policy is not None:
                for slot in active:
                    s_ = sched.slots[slot]
                    remaining = s_.request.gen_len - s_.metrics.tokens_out
                    k_vec[slot] = self._spec_policy.depth(
                        slot, int(pos_host[slot]), remaining)
            if active and self._store is not None:
                # page growth for the rows this step commits; exhaustion
                # preempts LIFO back to the queue head
                active, state = self._grow_or_preempt(
                    active, k_vec, sched, state, pos_host, histories)
            if active:
                if int(pos_host[active].max()) >= self.max_len:
                    # reachable only with admission=False (admission's
                    # over_budget check forbids it): fail loudly rather than
                    # silently clobbering the last cache row
                    raise RuntimeError(
                        f"active slot position {int(pos_host[active].max())} "
                        f"overran max_len={self.max_len}")
                K = int(k_vec.max())
                pos_vec = jnp.asarray(pos_host, jnp.int32)
                temps = jnp.asarray(temps_host)
                tables = pools = None
                if self._fused:
                    # steady-state fused path: this step reads/writes the
                    # pools THROUGH the block table — no page->lane gather
                    tables = self._build_tables(sched, active)
                    pools = self._sharded_device_pools()
                if K == 0:
                    # degraded path: EXACTLY today's decode step — same jitted
                    # fn, same sampler call, same key draw — so k=0
                    # speculation is token-for-token identical to PR 5 decode
                    tokens = jnp.asarray(pending_host[:, None], jnp.int32)
                    if self._fused:
                        logits, state, pools = self._decode_paged(
                            self.params, state, pools, tables, tokens,
                            pos_vec)
                        self._store.set_device_pools(pools)
                    else:
                        logits, state = self._decode(self.params, state,
                                                     tokens, pos_vec)
                    toks = np.asarray(self._sample(logits, self._next_key(),
                                                   temps))
                    decode_steps += 1
                    for slot in active:
                        rid = sched.slots[slot].request.rid
                        sched.step_done(slot)
                        pos_host[slot] += 1
                        pending_host[slot] = int(toks[slot])
                        outputs[rid].append(int(toks[slot]))
                        if slot in histories:
                            histories[slot].append(int(toks[slot]))
                        generated += 1
                        emitted_this_step += 1
                else:
                    # speculative round: draft -> ONE ragged batched verify ->
                    # accept longest prefix + corrected token -> commit
                    drafts = self._drafter.propose(active, histories, k_vec,
                                                   self.batch, K)
                    span_np = np.concatenate(
                        [pending_host[:, None], drafts], axis=1)
                    # the whole table takes the slab write (inactive rows
                    # included), so the guard covers every slot's position
                    assert_span_fits(pos_host, K + 1, self._state_len)
                    span = jnp.asarray(span_np, jnp.int32)
                    if self._fused:
                        logits, state, pools = self._verify_paged(
                            self.params, state, pools, tables, span, pos_vec)
                    else:
                        logits, state = self._verify(self.params, state, span,
                                                     pos_vec)
                    # sample the target token at EVERY span row (per-slot
                    # temperature); row j validates draft j+1, row m yields
                    # the corrected token for a slot accepting m drafts
                    tgt = np.asarray(self._sample(logits, self._next_key(),
                                                  temps))
                    m_vec = accept_span(drafts, tgt, k_vec)
                    n_commit = np.zeros(self.batch, np.int64)
                    for slot in active:
                        n_commit[slot] = m_vec[slot] + 1
                    if self._fused:
                        if self._commit_paged is not None:
                            state, pools = self._commit_paged(
                                self.params, state, pools, tables, span,
                                pos_vec, jnp.asarray(n_commit, jnp.int32))
                        self._store.set_device_pools(pools)
                    elif self._commit is not None:
                        # recurrent/hybrid: replay the accepted prefix of the
                        # span through the chunked-prefill path (per-slot
                        # n_commit real rows; 0 == exact identity, so
                        # rejected or inactive slots are never perturbed)
                        state = self._commit(
                            self.params, state, span, pos_vec,
                            jnp.asarray(n_commit, jnp.int32))
                    verify_steps += 1
                    for slot in active:
                        rid = sched.slots[slot].request.rid
                        m = int(m_vec[slot])
                        emit = [int(t) for t in drafts[slot, :m]]
                        emit.append(int(tgt[slot, m]))
                        sched.step_done(slot, n=len(emit))
                        if k_vec[slot] > 0:
                            sched.spec_round(slot, proposed=int(k_vec[slot]),
                                             accepted=m)
                            self._spec_policy.update(slot, int(k_vec[slot]),
                                                     m)
                            spec_proposed_total += int(k_vec[slot])
                            spec_accepted_total += m
                            spec_slot_rounds += 1
                        pos_host[slot] += len(emit)
                        pending_host[slot] = emit[-1]
                        outputs[rid].extend(emit)
                        if slot in histories:
                            histories[slot].extend(emit)
                        if self._drafter is not None:
                            self._drafter.on_commit(slot, m)
                        generated += len(emit)
                        emitted_this_step += len(emit)

            if active and self.mesh is not None:
                # steady-state audit: the step must have returned state (and
                # pools) in exactly the rule shardings it received them with
                self._check_steady_sharding(
                    state, self._store.device_pools() if self._fused else None)

            # -- phase 3: shared-step time attribution (prefill vs decode) ---
            decode_emitted += emitted_this_step
            slot_steps += len(active)
            t_step = time.perf_counter() - t_step0
            pre_share, _ = sched.attribute_step_time(
                t_step, chunk_tokens, active,
                decode_tokens=emitted_this_step)
            for task in ran:
                task.prefill_s += pre_share / max(len(ran), 1)

            if ran or active:
                step_log.append({"step": step,
                                 "prefill_rids": [t.req.rid for t in ran],
                                 "chunks": len(ran),
                                 "decoded": len(active),
                                 "emitted": emitted_this_step})

            # -- phase 4: completions (finished prefills + finished decodes) -
            for task in list(tasks):
                if task.chunk_idx < task.n_chunks:
                    continue
                if self._store is not None:
                    state, gen_inc = self._complete_paged(
                        task, sched, state, now, outputs, histories,
                        pending_host, pos_host, temps_host)
                    generated += gen_inc
                    tasks.remove(task)
                    continue
                # prefill complete: graft the donor into its reserved slot,
                # sample the first token, occupy
                slot = task.slot
                temps_host[slot] = self._slot_temperature(task.req)
                first = int(np.asarray(self._sample(
                    jnp.asarray(task.first_logits), self._next_key(),
                    jnp.asarray(temps_host[slot:slot + 1])))[0])
                validate_donor(state, task.donor,
                               self.model.state_batch_axes(state))
                state = self._insert(state, task.donor, slot)
                sched.place(task.req, slot)
                sched.add_prefill_time(slot, task.prefill_s)
                sched.first_token(slot, now())
                generated += 1
                outputs[task.req.rid] = [first]
                pending_host[slot] = first
                pos_host[slot] = task.fill
                # committed token history (prompt + emitted): the drafter's
                # lookup corpus, reset on every slot reuse
                histories[slot] = [int(t) for t in
                                   np.asarray(task.req.tokens)] + [first]
                if self._spec_policy is not None:
                    self._spec_policy.reset(slot)
                if self._drafter is not None:
                    self._drafter.on_graft(task.req.rid, slot,
                                           histories[slot])
                tasks.remove(task)
                if sched.slot_done(slot):           # gen_len == 1 edge case
                    sched.finish(slot, now())
                    state = self._reset(state, slot)
                    if self._drafter is not None:
                        self._drafter.on_finish(slot)
            for slot in list(active):
                if sched.slot_done(slot):
                    rid_done = sched.slots[slot].request.rid
                    sched.finish(slot, now())
                    state = self._reset(state, slot)
                    if self._store is not None:
                        self._store.free(rid_done)
                        self._act_stamp.pop(slot, None)
                    if self._drafter is not None:
                        self._drafter.on_finish(slot)

            if ran or active:
                step += 1           # a unified step actually did device work
            elif not sched.active_slots() and not tasks:
                nxt = sched.next_arrival_s()
                if nxt is not None and not sched.queue and not self._inbox:
                    # idle until the next scheduled arrival
                    time.sleep(max(0.0, min(nxt - now(), 0.05)))

        wall = max(now(), 1e-9)
        finished = sched.finished
        ttfts = [m.ttft_s for m in finished]

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else 0.0

        by_bucket: dict[int, list[float]] = {}
        for m in finished:
            by_bucket.setdefault(m.bucket, []).append(m.ttft_s)
        ttft_by_bucket = {
            b: {"n": len(xs), "p50_s": pct(xs, 50), "p90_s": pct(xs, 90),
                "p99_s": pct(xs, 99)}
            for b, xs in sorted(by_bucket.items())
        }

        report = {
            "arch": self.cfg.name,
            "requests": len(finished),
            "generated_tokens": generated,
            "decode_tokens_per_s": generated / wall,
            "steps": step,
            "wall_s": wall,
            "padded_slot_steps_steady": padded_steady,
            "prefill_chunk": chunk,
            "buckets": list(self.policy.buckets),
            "prefill_tokens": prefill_tokens_total,
            "ttft_s_mean": float(np.mean(ttfts)) if ttfts else 0.0,
            "ttft_by_bucket": ttft_by_bucket,
            "sla_hit_rate": sched.sla_hit_rate(),
            "slot_reuse": sched.slot_reuse(),
            "admission_log": sched.admission_log,
            "step_log": step_log,
            "jit_cache": self.jit_cache_sizes(),
            "per_request": [asdict(m) for m in finished],
            "refused": [{"rid": r.rid, "reason": r.reason}
                        for r in sched.refused],
            "outputs": outputs,
        }
        if self.mesh is not None:
            shards = dist_sharding.mesh_shards(self.mesh)
            param_bytes = sum(x.nbytes for x in jax.tree.leaves(self.params))
            state_bytes = sum(x.nbytes for x in jax.tree.leaves(state))
            pool_bytes = 0
            if self._store is not None:
                pool_bytes = self._store.hbm_bytes_resident()
            report["mesh"] = {
                "axes": dist_sharding.mesh_axis_sizes(self.mesh),
                "shards": shards,
                "dp": dist_sharding.dp_size(self.mesh),
                "tp": dist_sharding.tp_size(self.mesh),
                # the compiled-once / zero-resharding claim, audited per step
                "reshard_events": self._reshard_events,
                "param_bytes_per_shard": param_bytes / shards,
                "state_bytes_per_shard": state_bytes / shards,
                "hbm_resident_bytes_per_shard":
                    (param_bytes + state_bytes + pool_bytes) / shards,
                "comms_bytes_per_step":
                    self.cost_model.comms_bytes_per_step()
                    if self.cost_model is not None else 0.0,
            }
            if self.cost_model is not None:
                info = self.cost_model.mesh_info()
                if info is not None:
                    report["mesh"]["pricing"] = info
        if self.cost_model is not None:
            report["cost_model"] = {
                "decode_bytes_per_step": self.cost_model.decode_bytes_per_step(),
                "step_seconds": self.cost_model.step_seconds(),
                "prefill_seconds_largest_bucket":
                    self.cost_model.prefill_seconds(self.policy.buckets[-1]),
            }
            if self.spec is not None:
                report["cost_model"]["verify_seconds_k_max"] = \
                    self.cost_model.verify_seconds(self._k_max)
        if self._store is not None:
            st = self._store
            budget_bytes = st.n_pages * st.page_bytes
            contig_slot = max(st.contiguous_bytes_per_slot(self.max_len), 1)
            report["paged"] = {
                "page_size": st.page,
                "page_bytes": st.page_bytes,
                "n_pages": st.n_pages,
                "hbm_budget_bytes": budget_bytes,
                # bytes priced from ACTUAL pages allocated, not worst case
                "hbm_bytes_resident": st.hbm_bytes_resident(),
                "hbm_bytes_resident_peak": st.pages_used_peak * st.page_bytes,
                "pages_used_peak": st.pages_used_peak,
                "resident_requests": st.resident_requests(),
                "resident_requests_peak": st.resident_peak,
                # what a contiguous max-len slot table could hold at the
                # SAME HBM budget — the residency headline's denominator
                "contiguous_resident_bound": budget_bytes // contig_slot,
                "prefix_hits": st.prefix_store.hits,
                "prefix_misses": st.prefix_store.misses,
                "prefix_entries": len(st.prefix_store.entries),
                "cow_copies": st.cow_copies,
                "preemptions": self._preempt_count,
                "int8": st.int8,
                "fused": self._fused,
                # host spill tier: cold unshared pages evicted to host RAM
                "spills": st.spills,
                "rehydrates": st.rehydrates,
                "spilled_pages": st.spilled_pages(),
                "host_spill_bytes": st.host_spill_bytes(),
                # fused-decode accounting: lane_activations counts full
                # page->lane gathers (fallback families only); fused
                # activations restore just the recurrent tail and skip the
                # KV gather entirely — gather_bytes_eliminated is the fp
                # bytes those skipped gathers would have moved
                "lane_activations": self._lane_activations,
                "tail_restores": self._tail_restores,
                "gather_bytes_eliminated": self._gather_bytes_eliminated,
                "gather_bytes_eliminated_per_step":
                    self._gather_bytes_eliminated
                    / max(decode_steps + verify_steps, 1),
            }
        if self.spec is not None:
            # accepted-token rate + mean accepted span, overall and by bucket
            by_b: dict[int, list[int]] = {}
            for m in finished:
                acc = by_b.setdefault(m.bucket, [0, 0, 0, 0])
                acc[0] += 1
                acc[1] += m.spec_proposed
                acc[2] += m.spec_accepted
                acc[3] += m.verify_rounds
            accept_by_bucket = {
                b: {"n": n, "proposed": p, "accepted": a,
                    "accepted_rate": a / max(p, 1),
                    # each speculating round emits accepted + 1 corrected
                    "mean_accepted_span": (a + r) / max(r, 1)}
                for b, (n, p, a, r) in sorted(by_b.items())
            }
            report["spec"] = {
                "drafter": self.spec.drafter,
                "k_max": self._k_max,
                "decode_steps": decode_steps,
                "verify_steps": verify_steps,
                "drafted_tokens": spec_proposed_total,
                "accepted_tokens": spec_accepted_total,
                "accepted_rate":
                    spec_accepted_total / max(spec_proposed_total, 1),
                "mean_accepted_span":
                    (spec_accepted_total + spec_slot_rounds)
                    / max(spec_slot_rounds, 1),
                # the speedup headline: < 1.0 means speculation emitted more
                # tokens than it ran phase-2 device steps
                "steps_per_emitted_token":
                    (decode_steps + verify_steps) / max(decode_emitted, 1),
                # batching-independent version: per-SLOT step participations
                # per emitted token — exactly 1.0 for plain decode, < 1.0
                # iff verify rounds accepted drafts
                "slot_steps_per_emitted_token":
                    slot_steps / max(decode_emitted, 1),
                "accept_by_bucket": accept_by_bucket,
            }
        return report
