"""Train-step builder: loss + grad + AdamW update, with optional microbatch
gradient accumulation (scan) and int8 error-feedback gradient compression.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.model import Model

from . import optimizer as opt_mod
from .optimizer import OptConfig


def make_train_step(model: Model, opt_cfg: OptConfig, *,
                    microbatches: int = 1, compress_grads: bool = False,
                    mesh=None):
    """Returns train_step(train_state, batch) -> (train_state, metrics).

    train_state = {"params", "opt"}; batch = {"tokens", "labels", ...}.
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat=True)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        # split the leading batch dim into microbatches and scan-accumulate
        def reshape(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mb = jax.tree.map(reshape, batch)

        def body(acc, micro):
            (loss, metrics), grads = grad_fn(params, micro)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(jnp.add, acc_g, grads)
            return (acc_g, acc_l + loss), metrics

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), metrics = jax.lax.scan(body, (zero_g, 0.0), mb)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / microbatches, metrics, grads

    def train_step(train_state, batch):
        params, opt_state = train_state["params"], train_state["opt"]
        loss, metrics, grads = compute_grads(params, batch)
        if compress_grads:
            from repro.dist.compression import compress_decompress
            grads, cerr = compress_decompress(grads)
            metrics = {**metrics, "compress_err": cerr}
        new_params, new_opt, opt_metrics = opt_mod.apply_updates(
            opt_cfg, params, grads, opt_state)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, **metrics, **opt_metrics})

    return train_step


def init_train_state(model: Model, opt_cfg: OptConfig, key):
    params = model.init(key)
    return {"params": params, "opt": opt_mod.init_opt_state(opt_cfg, params)}
