# Pallas TPU hot-spot kernels. Each subpackage: kernel.py (pl.pallas_call +
# explicit BlockSpec VMEM tiling), ops.py (jit'd public wrapper with the
# interpret switch), ref.py (pure-jnp oracle used by tests and by the cpu_xla
# TSL definitions). Kernels are wired into the generated TSL via the UPD
# (tsl_data/primitives/*.yaml) — the framework never calls them directly.
