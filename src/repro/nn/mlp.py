"""Dense MLP blocks (SwiGLU / GELU) on TSL primitives."""

from __future__ import annotations

from repro.tsl_api import ops as tsl

from .common import dense_init, split_keys


def init_mlp(key, cfg, dtype, d_ff: int | None = None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = split_keys(key, 3)
    if cfg.act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d, ff), dtype),
            "w_up": dense_init(ks[1], (d, ff), dtype),
            "w_down": dense_init(ks[2], (ff, d), dtype),
        }
    return {
        "w_in": dense_init(ks[0], (d, ff), dtype),
        "w_out": dense_init(ks[1], (ff, d), dtype),
    }


def mlp_forward(p, x, cfg):
    if "w_gate" in p:
        g = tsl.matmul(x, p["w_gate"])
        u = tsl.matmul(x, p["w_up"])
        return tsl.matmul(tsl.swiglu(g, u), p["w_down"])
    h = tsl.gelu(tsl.matmul(x, p["w_in"]))
    return tsl.matmul(h, p["w_out"])
