from .arch import ArchConfig
from .registry import ARCH_IDS, get_config
from .shapes import SHAPES, ShapeCell, applicable

__all__ = ["ArchConfig", "ARCH_IDS", "get_config", "SHAPES", "ShapeCell", "applicable"]
