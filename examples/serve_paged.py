"""Paged slot memory demo: many requests sharing one system prompt, served
inside an HBM budget that a contiguous slot table could spend on only TWO
max-length reservations.

The paged engine charges HBM for pages actually produced, shares the system
prompt's pages copy-on-write through the content-addressed prefix store
(prefilled ONCE, asserted via the chunk count), and parks completed prefills
in pages until a lane frees — so residency is bounded by pages, not lanes.
Steady-state decode is FUSED (ISSUE 9): every step reads the pools through
the block table via ``attention_decode_paged``, so no page->lane gather ever
runs (asserted: zero lane activations). A second, tighter-budget run drives
the host-spill tier: cold parked pages evicted to host arrays and rehydrated
on reactivation, token counts intact:

    PYTHONPATH=src python examples/serve_paged.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.serve import PagedConfig, Request, ServeEngine  # noqa: E402

N_REQUESTS = 10
SYSTEM_LEN = 16          # shared system prompt (page-aligned at page 16)
UNIQUE_LEN = 8
GEN_LEN = 4
MAX_LEN = 96
PAGE = 16


def main():
    cfg = get_config("qwen1.5-0.5b").reduced()

    # budget = what a CONTIGUOUS slot table spends on just 2 worst-case
    # lanes; the paged engine must fit far more residency into the same HBM
    probe = ServeEngine(cfg, batch=2, max_len=MAX_LEN, seed=0,
                        paged=PagedConfig(page_size=PAGE))
    budget = 2 * probe._store.contiguous_bytes_per_slot(MAX_LEN)
    del probe

    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab, SYSTEM_LEN).astype(np.int32)
    requests = []
    for i in range(N_REQUESTS):
        toks = np.concatenate(
            [system, rng.integers(0, cfg.vocab, UNIQUE_LEN).astype(np.int32)])
        requests.append(Request(rid=f"r{i}", tokens=toks, gen_len=GEN_LEN,
                                shared_prefix_len=SYSTEM_LEN))

    jax.clear_caches()
    engine = ServeEngine(
        cfg, batch=2, max_len=MAX_LEN, seed=0,
        paged=PagedConfig(page_size=PAGE, hbm_budget_bytes=budget,
                          max_inflight_prefills=N_REQUESTS))
    report = engine.run(requests)

    pg = report["paged"]
    print(f"[example] {report['requests']} requests on 2 lanes, "
          f"budget {budget / 1e6:.2f} MB "
          f"(= {pg['contiguous_resident_bound']} contiguous slots)")
    print(f"[example] resident peak {pg['resident_requests_peak']} requests, "
          f"{pg['pages_used_peak']}/{pg['n_pages']} pages "
          f"({pg['hbm_bytes_resident_peak'] / 1e6:.2f} MB peak)")
    print(f"[example] prefix store: {pg['prefix_hits']} hits / "
          f"{pg['prefix_misses']} miss, cow copies {pg['cow_copies']}")

    assert report["requests"] == N_REQUESTS, report
    assert all(len(report["outputs"][r.rid]) == GEN_LEN for r in requests)

    # the headline: >= 4x the residency of the contiguous bound, same HBM
    bound = pg["contiguous_resident_bound"]
    assert pg["resident_requests_peak"] >= 4 * bound, pg

    # the shared system prompt was prefilled exactly once
    assert pg["prefix_hits"] == N_REQUESTS - 1, pg
    assert pg["prefix_misses"] == 1, pg
    chunk = engine.policy.chunk
    bucket = report["per_request"][0]["bucket"]
    chunks = sum(e["chunks"] for e in report["step_log"])
    want = bucket // chunk + (N_REQUESTS - 1) * ((bucket - SYSTEM_LEN) // chunk)
    assert chunks == want, (chunks, want)
    print(f"[example] prefill chunks {chunks} == {want} "
          f"(system prompt prefilled once)")

    # ISSUE 9: steady-state KV-family decode is fused — the pools are read
    # through the block table, and NO page->lane gather ever ran
    assert pg["fused"], pg
    assert pg["lane_activations"] == 0, pg
    assert pg["tail_restores"] > 0, pg
    assert pg["gather_bytes_eliminated"] > 0, pg
    print(f"[example] fused decode: 0 lane activations, "
          f"{pg['tail_restores']} tails-only restores, "
          f"{pg['gather_bytes_eliminated'] / 1e3:.0f} kB of gather "
          f"eliminated")

    # -- host spill tier: a budget too small for the parked population ------
    small_page = 8
    jax.clear_caches()
    probe = ServeEngine(cfg, batch=2, max_len=24, seed=0,
                        paged=PagedConfig(page_size=small_page))
    tight = 5 * probe._store.page_bytes
    del probe
    rng = np.random.default_rng(1)
    spill_reqs = [
        Request(rid=f"s{i}", tokens=rng.integers(0, cfg.vocab, 8)
                .astype(np.int32), gen_len=4, arrival_s=i * 0.02)
        for i in range(5)]
    jax.clear_caches()
    spill_rep = ServeEngine(
        cfg, batch=2, max_len=24, seed=0,
        paged=PagedConfig(page_size=small_page,
                          hbm_budget_bytes=tight)).run(spill_reqs)
    sp = spill_rep["paged"]
    assert all(len(spill_rep["outputs"][r.rid]) == 4 for r in spill_reqs)
    assert sp["spills"] >= 1 and sp["rehydrates"] >= 1, sp
    assert sp["host_spill_bytes"] == 0, sp       # everything came back
    print(f"[example] spill tier: {sp['spills']} spills / "
          f"{sp['rehydrates']} rehydrates under a {tight / 1e3:.0f} kB "
          f"budget, all tokens emitted")


if __name__ == "__main__":
    main()
