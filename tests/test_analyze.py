"""TSL-Check (ISSUE 6): the semantic static-analysis GPO.

Covers every analyzer family with small typed corpora built via
``CorpusIR.from_defs``, the suppression/baseline mechanics, the pipeline
insertion point, the kernel-file lint on seeded fixtures, and — the headline
acceptance criterion — that the shipped repo corpus lints clean at
``--fail-on=error`` while a seeded-violation corpus does not.
"""

import ast
import logging
import textwrap

import pytest

from repro.analyze import (AnalysisReport, AnalyzeGPO, CODES, RenderedBody,
                           availability_matrix, check_cost_channel,
                           check_coverage, check_safety, lint_kernel_file,
                           lint_rendered_bodies, render_bodies, run_analysis)
from repro.analyze.cost_check import check_formula, formula_symbols
from repro.core import load_corpus
from repro.core.corpus import CorpusPipeline
from repro.core.model import (CorpusIR, ImplDef, ParamDef, PrimitiveDef,
                              TargetDef, TestDef)


# -- tiny typed-corpus builders ----------------------------------------------

def mk_target(name="t0", flags=("xla",), ctypes=("float32",), lanes=128,
              sublanes=8):
    return TargetDef(
        name=name, vendor="test", flags=tuple(flags), ctypes=tuple(ctypes),
        default_ctype=ctypes[0], lanes=lanes, sublanes=sublanes,
        mxu=(128, 128), vmem_bytes=1 << 24, hbm_bytes=1 << 30,
        peak_flops_bf16=1e12, hbm_bw=1e11, ici_bw=1e10, ici_links=1)


def mk_impl(target="t0", ctypes=("float32",), flags=("xla",),
            impl="return x\n", **kw):
    return ImplDef(target_extension=target, ctypes=tuple(ctypes),
                   flags=tuple(flags), implementation=impl, **kw)


def mk_prim(name, defs, params=("x",), tested=True, **kw):
    tests = (TestDef(name="t", implementation="pass"),) if tested else ()
    return PrimitiveDef(
        name=name, group="g", brief="b",
        parameters=tuple(ParamDef(name=p) for p in params),
        returns_ctype="register", definitions=tuple(defs), tests=tests, **kw)


def mk_corpus(prims, targets=None):
    targets = targets if targets is not None else [mk_target()]
    return CorpusIR.from_defs({t.name: t for t in targets},
                              {p.name: p for p in prims})


# -- finding / report mechanics ----------------------------------------------

def test_code_registry_is_consistent():
    assert all(c.severity in ("error", "warn", "info") for c in CODES.values())
    assert all(code == c.code for code, c in CODES.items())
    assert all(c.rationale for c in CODES.values())


def test_report_rejects_unknown_code():
    rep = AnalysisReport()
    with pytest.raises(KeyError):
        rep.add("TSL999", "nope")


def test_exit_code_gates():
    rep = AnalysisReport()
    rep.add("TSL023", "warn-level finding", subject="primitive:p")
    rep.add("TSL015", "info-level finding", subject="primitive:p")
    assert rep.exit_code("error") == 0
    assert rep.exit_code("warn") == 1
    assert rep.exit_code("info") == 1
    assert rep.exit_code("never") == 0
    rep.add("TSL014", "error-level finding", subject="primitive:p")
    assert rep.exit_code("error") == 1
    assert rep.exit_code("never") == 0


def test_baseline_masks_identity_not_location():
    rep = AnalysisReport()
    rep.add("TSL023", "dead", subject="primitive:p", location="def[1] line 9")
    ident = rep.findings[0].identity()
    assert "line" not in ident          # location never participates
    rep.apply_baseline({ident})
    assert rep.findings[0].baselined and not rep.findings[0].active
    assert rep.exit_code("warn") == 0
    assert rep.counts()["baselined"] == 1


def test_suppression_keeps_finding_in_report():
    rep = AnalysisReport()
    rep.add("TSL032", "dot", subject="primitive:p", location="def[0] t0 line 2")
    rep.apply_suppressions(lambda f: f.code == "TSL032")
    assert rep.findings and rep.findings[0].suppressed
    assert not rep.active_findings()
    assert "[suppressed]" in rep.findings[0].render()


def test_renderings_cover_all_findings():
    rep = AnalysisReport()
    rep.add("TSL014", "missing term", subject="primitive:p",
            location="target:t0")
    md, js, txt = rep.to_markdown(), rep.to_json(), rep.to_text()
    assert "TSL014" in md and "target:t0" in md
    assert js["findings"][0]["severity"] == "error"
    assert "1 error(s)" in txt


# -- cost channel (TSL01x) ----------------------------------------------------

def test_formula_whitelist():
    assert check_formula("2*B*H*(S+1)//4")[0] is None
    assert check_formula("B**2 % 3 - -H")[0] is None
    assert check_formula("B*")[0] == "TSL010"
    assert check_formula("__import__('os')")[0] == "TSL011"
    assert check_formula("B[0]")[0] == "TSL011"
    assert check_formula("B.real")[0] == "TSL011"
    assert check_formula("B if H else 1")[0] == "TSL011"
    assert check_formula("'4'")[0] == "TSL011"
    assert formula_symbols("2*B*H + S") == {"B", "H", "S"}


def test_cost_channel_symbol_binding():
    prim = mk_prim("p", [mk_impl(cost={"flops": "N*QQ"})],
                   cost_shapes=("N",))
    rep = check_cost_channel(mk_corpus([prim]))
    assert rep.codes() == {"TSL012"}
    assert "QQ" in rep.findings[0].message


def test_cost_channel_missing_shape_declaration():
    prim = mk_prim("p", [mk_impl(cost={"flops": "N"})])
    assert check_cost_channel(mk_corpus([prim])).codes() == {"TSL013"}


def test_cost_channel_bench_without_cost():
    prim = mk_prim("p", [mk_impl()], bench={"setup": "x = 1", "n_iter": 1})
    assert check_cost_channel(mk_corpus([prim])).codes() == {"TSL015"}


def test_priced_primitive_gap_and_fix():
    bad = mk_prim("attention_decode", [mk_impl(cost={"flops": "B"})],
                  cost_shapes=("B",))
    rep = check_cost_channel(mk_corpus([bad]))
    assert "TSL014" in rep.codes()
    assert any("bytes" in f.message and "comms" in f.message
               for f in rep.findings if f.code == "TSL014")

    good = mk_prim("attention_decode",
                   [mk_impl(cost={"flops": "B", "bytes": "B", "comms": "B"})],
                   cost_shapes=("B",))
    assert "TSL014" not in check_cost_channel(mk_corpus([good])).codes()


def test_priced_primitive_bench_requires_every_candidate_priced():
    # with a bench: block ANY valid candidate can win selection, so one
    # unpriced candidate breaks the static guarantee even if the heuristic
    # winner is priced
    full = mk_impl(flags=("xla", "fast"),
                   cost={"flops": "B", "bytes": "B", "comms": "B"})
    bare = mk_impl(flags=("xla",))
    prim = mk_prim("ssd_scan", [full, bare], cost_shapes=("B",),
                   bench={"setup": "x = 1", "n_iter": 1})
    corpus = mk_corpus([prim], targets=[mk_target(flags=("xla", "fast"))])
    rep = check_cost_channel(corpus)
    assert any(f.code == "TSL014" and "def[1]" in f.message
               for f in rep.findings)


# -- coverage matrix (TSL02x) -------------------------------------------------

def test_coverage_matrix_and_findings():
    t0, t1 = mk_target("t0"), mk_target("t1")
    partial = mk_prim("partial", [mk_impl("t0")])
    untested = mk_prim("untested", [mk_impl("t0"), mk_impl("t1")],
                       tested=False)
    ghost = mk_prim("ghost", [mk_impl("t0"),
                              mk_impl("t0", flags=("no_such_flag",))])
    corpus = mk_corpus([partial, untested, ghost], targets=[t0, t1])

    matrix = availability_matrix(corpus)
    assert set(matrix["partial"]) == {"t0"}
    assert set(matrix["untested"]) == {"t0", "t1"}

    rep = check_coverage(corpus)
    by = {}
    for f in rep.findings:
        by.setdefault(f.code, []).append(f)
    assert any("partial" in f.subject for f in by["TSL020"])
    assert any("untested" in f.subject for f in by["TSL021"])
    assert any("ghost" in f.subject and "no_such_flag" in f.message
               for f in by["TSL022"])
    # the unknown-flag def is TSL022, not double-reported as TSL023
    assert not any("ghost" in f.subject for f in by.get("TSL023", []))


def test_dead_candidate_detection():
    t0 = mk_target("t0", flags=("xla", "fast"))
    loser = mk_impl(flags=("xla",))
    winner = mk_impl(flags=("xla", "fast"))
    dead = mk_prim("dead", [loser, winner])
    rep = check_coverage(mk_corpus([dead], targets=[t0]))
    hits = [f for f in rep.findings if f.code == "TSL023"]
    assert len(hits) == 1 and hits[0].location == "def[0]"

    # a bench: block makes every valid candidate reachable
    benched = mk_prim("benched", [loser, winner],
                      bench={"setup": "x = 1", "n_iter": 1})
    rep = check_coverage(mk_corpus([benched], targets=[t0]))
    assert not any(f.code == "TSL023" for f in rep.findings)


def test_ctype_not_offered_by_target():
    prim = mk_prim("p", [mk_impl(ctypes=("float32", "int8"))])
    rep = check_coverage(mk_corpus([prim]))
    assert any(f.code == "TSL024" and "int8" in f.message
               for f in rep.findings)


# -- stage-1 body rendering (TSL040 infrastructure) ---------------------------

def test_render_bodies_renders_against_target_sru():
    prim = mk_prim("p", [mk_impl(impl="return x * {{ sru.lanes }}\n")])
    bodies = render_bodies(mk_corpus([prim]))
    assert len(bodies) == 1 and not bodies[0].error
    assert "x * 128" in bodies[0].source
    assert bodies[0].tree is not None and bodies[0].lanes == 128


def test_render_bodies_reports_failures_not_crashes():
    bad_jinja = mk_prim("badj", [mk_impl(impl="{% if x %}return x\n")])
    bad_py = mk_prim("badp", [mk_impl(impl="return ((x\n")])
    bodies = render_bodies(mk_corpus([bad_jinja, bad_py]))
    errs = {b.primitive: b.error for b in bodies}
    assert "render failed" in errs["badj"]
    assert "does not parse" in errs["badp"]
    rep = run_analysis(mk_corpus([bad_jinja, bad_py]), kernel_roots=())
    assert "TSL040" in rep.codes()


# -- implementation-body safety (TSL04x) --------------------------------------

def _rb(src):
    src = textwrap.dedent(src)
    return RenderedBody("p", 0, "t0", "float32", 8, 128, src, ast.parse(src))


def test_safety_host_numpy_only_inside_functions():
    rep = check_safety([_rb("""
        import numpy as np
        TABLE = np.arange(8)          # host constant table: legitimate

        def _impl(x):
            return np.tanh(x)         # traced: forbidden
    """)])
    hits = [f for f in rep.findings if f.code == "TSL041"]
    assert len(hits) == 1 and "line 6" in hits[0].location


def test_safety_io_callback_nondet():
    rep = check_safety([_rb("""
        def _impl(x):
            print(x)
            y = jax.pure_callback(f, x, x)
            z = jax.debug.callback(f, x)
            t = time.time()
            r = np.random.rand()
            return os.getpid()
    """)])
    assert {"TSL041", "TSL042", "TSL043", "TSL044"} <= rep.codes()
    msgs = " ".join(f.message for f in rep.findings)
    assert "pure_callback" in msgs and "debug.callback" in msgs


def test_safety_jax_random_is_exempt():
    rep = check_safety([_rb("""
        def _impl(x, key):
            return x + jax.random.normal(key, x.shape)
    """)])
    assert "TSL044" not in rep.codes()


# -- Pallas tiling lint (TSL03x) ----------------------------------------------

BAD_KERNEL = textwrap.dedent("""\
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl


    def kernel(x_ref, o_ref):
        o_ref[...] = jnp.dot(x_ref[...], x_ref[...])


    def run(x, bm=16, bn=96):
        m, n = x.shape
        grid = (m // bm, n // bn)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        )(x)
""")

GOOD_KERNEL = textwrap.dedent("""\
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl


    def kernel(x_ref, o_ref):
        o_ref[...] = jnp.dot(x_ref[...], x_ref[...],
                             preferred_element_type=jnp.float32)


    def run(x, bm=16, bn=128):
        m, n = x.shape
        assert m % bm == 0 and n % bn == 0
        grid = (m // bm, n // bn)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        )(x)
""")


def test_kernel_lint_flags_seeded_violations(tmp_path):
    path = tmp_path / "bad" / "kernel.py"
    path.parent.mkdir()
    path.write_text(BAD_KERNEL)
    rep = lint_kernel_file(path, sublanes=8, lanes=128, root=tmp_path)
    codes = [f.code for f in rep.findings]
    assert codes.count("TSL030") == 2          # bn=96 in both BlockSpecs
    assert codes.count("TSL031") == 2          # m//bm and n//bn unguarded
    assert codes.count("TSL032") == 1          # bare jnp.dot
    assert all(f.subject == "file:bad/kernel.py" for f in rep.findings)


def test_kernel_lint_accepts_guarded_aligned_kernel(tmp_path):
    path = tmp_path / "kernel.py"
    path.write_text(GOOD_KERNEL)
    rep = lint_kernel_file(path, sublanes=8, lanes=128)
    assert not rep.findings


def test_kernel_lint_syntax_error_is_tsl040(tmp_path):
    path = tmp_path / "kernel.py"
    path.write_text("def broken(:\n")
    rep = lint_kernel_file(path)
    assert rep.codes() == {"TSL040"}


def test_rendered_body_lint_uses_target_geometry():
    # same body, two geometries: a (8, 96) block is clean for lanes=32
    # (gpu warp) and misaligned for lanes=128 (tpu)
    impl = ("block = pl.BlockSpec((8, 96), lambda i: (i, 0))\n"
            "return x\n")
    tpu = mk_target("tpu", lanes=128, sublanes=8)
    gpu = mk_target("gpu", lanes=32, sublanes=1)
    prim = mk_prim("p", [mk_impl("tpu", impl=impl), mk_impl("gpu", impl=impl)])
    bodies = render_bodies(mk_corpus([prim], targets=[tpu, gpu]))
    rep = lint_rendered_bodies(bodies)
    hits = [f for f in rep.findings if f.code == "TSL030"]
    assert len(hits) == 1 and "tpu" in hits[0].location


# -- GPO + whole-repo acceptance ----------------------------------------------

@pytest.fixture(scope="module")
def repo_report():
    return run_analysis(load_corpus())


def test_analyze_gpo_inserts_after_validate():
    pipe = CorpusPipeline()
    gpo = AnalyzeGPO(fail_on="never")
    pipe.insert_after("validate", gpo)
    assert pipe.names() == ["template-check", "validate", "analyze"]
    corpus = pipe.build()
    assert gpo.report is not None
    assert len(corpus.primitives) > 20          # corpus still fully built
    # repo corpus has no error-severity findings -> a strict fail_on="error"
    # build must also pass
    strict = CorpusPipeline()
    strict.insert_after("validate", AnalyzeGPO(fail_on="error"))
    strict.build()


def test_repo_corpus_lints_clean_at_fail_on_error(repo_report):
    """ISSUE 6 acceptance: `analyze --fail-on=error` exits 0 on the repo."""
    rep = repo_report
    assert rep.exit_code("error") == 0, [
        f.render() for f in rep.active_findings() if f.severity == "error"]


def test_expert_ffn_suppression_is_exercised(repo_report):
    """The shipped corpus demonstrates lint: {suppress: [...]} — expert_ffn's
    f32-upcast einsums suppress TSL032 per definition."""
    rep = repo_report
    sup = [f for f in rep.findings
           if f.suppressed and f.subject == "primitive:expert_ffn"]
    assert sup and all(f.code == "TSL032" for f in sup)
    assert not any(f.active and f.code == "TSL032"
                   and f.subject == "primitive:expert_ffn"
                   for f in rep.findings)


def test_every_serving_cost_formula_statically_verified(repo_report):
    """The two cost terms the serving scheduler actually evaluates must be
    guaranteed for every target (no TSL014 anywhere on the repo corpus)."""
    assert not any(f.code == "TSL014"
                   for f in repo_report.active_findings())


# -- satellite: scheduler fallback attribution --------------------------------

def test_scheduler_cost_fallback_warns_once_with_tsl014(monkeypatch, caplog):
    import repro.tsl_api as tsl_api
    from repro.configs import get_config
    from repro.serve import scheduler as sched

    def missing_term(*a, **k):
        raise KeyError("attention_decode")

    monkeypatch.setattr(tsl_api, "cost", missing_term)
    monkeypatch.setattr(sched, "_warned_cost_terms", set())
    cfg = get_config("qwen1.5-0.5b").reduced()
    adm = sched.CostModelAdmission(cfg, batch=2, max_len=32)
    with caplog.at_level(logging.WARNING, logger="repro.serve.scheduler"):
        adm.decode_bytes_per_step()
        adm.decode_bytes_per_step(16)       # second hit: deduplicated
    msgs = [r.getMessage() for r in caplog.records
            if "TSL014" in r.getMessage()]
    assert len(msgs) == 1
    assert "attention_decode" in msgs[0] and "bytes" in msgs[0]
    assert "repro.core analyze" in msgs[0]


def test_scheduler_comms_fallback_warning_is_distinct(monkeypatch, caplog):
    """Satellite: a missing ``comms`` term warns with its OWN wording — it
    mis-prices mesh collective traffic, not the single-device roofline —
    and still dedups per (primitive, term)."""
    import repro.tsl_api as tsl_api
    from repro.configs import get_config
    from repro.serve import scheduler as sched

    def missing_term(*a, **k):
        raise KeyError("attention_decode")

    monkeypatch.setattr(tsl_api, "cost", missing_term)
    monkeypatch.setattr(sched, "_warned_cost_terms", set())

    class _FakeMesh:
        axis_names = ("data", "model")
        import numpy as _np
        devices = _np.empty((2, 4), dtype=object)

    cfg = get_config("qwen1.5-0.5b").reduced()
    adm = sched.CostModelAdmission(cfg, batch=2, max_len=32, mesh=_FakeMesh())
    with caplog.at_level(logging.WARNING, logger="repro.serve.scheduler"):
        adm.comms_bytes_per_step()
        adm.comms_bytes_per_step(16)        # dedup: one warning only
    msgs = [r.getMessage() for r in caplog.records
            if "TSL014" in r.getMessage()]
    comms_msgs = [m for m in msgs if "'comms'" in m]
    assert len(comms_msgs) == 1
    assert "attention_decode" in comms_msgs[0]
    assert "collective" in comms_msgs[0]     # names the mesh consequence
    assert "repro.core analyze" in comms_msgs[0]
    # and the wording differs from the flops/bytes fallback message
    assert "roofline" not in comms_msgs[0]
