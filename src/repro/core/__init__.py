"""TSLGen-JAX — the paper's generator framework (DESIGN.md §1/§3).

Public surface:
    load_library(target=...)    -> generated + imported TSL module
    generate_library(config)    -> on-disk package (artifact-cache aware)
    generate_all(targets)       -> many targets off ONE validated corpus
    load_corpus(upd_paths)      -> immutable CorpusIR (validation memo)
    ArtifactCache, CacheKey, GENERATOR_VERSION — content-addressed store
    GenConfig, Pipeline, CorpusPipeline, core_pipeline — extension port
"""

from .cache import GENERATOR_VERSION, ArtifactCache, CacheKey
from .corpus import CorpusPipeline, corpus_cache_clear, load_corpus
from .library import generate_all, generate_library, load_library
from .model import CorpusBuild, CorpusIR, GenConfig, GenerationResult
from .pipeline import GenerationError, Pipeline, core_pipeline

__all__ = [
    "load_library",
    "generate_library",
    "generate_all",
    "load_corpus",
    "corpus_cache_clear",
    "GenConfig",
    "CorpusBuild",
    "CorpusIR",
    "GenerationResult",
    "Pipeline",
    "CorpusPipeline",
    "core_pipeline",
    "GenerationError",
    "ArtifactCache",
    "CacheKey",
    "GENERATOR_VERSION",
]
