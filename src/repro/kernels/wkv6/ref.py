"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence (naive time scan).

Per head (arXiv:2404.05892, data-dependent decay):

    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t          S: (B, H, K, V)

with r,k,w (B,T,H,K), v (B,T,H,V), u (H,K) bonus; w in (0,1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_scan(r, k, v, w, u, *, s0=None):
    """Returns (y, s_final): y (B,T,H,V), s (B,H,K,V). f32 internally."""
    bsz, t, h, dk = r.shape
    dv = v.shape[-1]
    rf, kf, vf, wf = (z.astype(jnp.float32) for z in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((bsz, h, dk, dv), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp            # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = kt[..., None] * vt[..., None, :]          # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    sT, ys = jax.lax.scan(
        step, s0,
        (rf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
         vf.transpose(1, 0, 2, 3), wf.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), sT


def wkv6_decode_step(rt, kt, vt, wt, u, s):
    """One decode step; shapes as in `step` above, s (B,H,K,V) f32."""
    sf = s.astype(jnp.float32)
    kv = kt.astype(jnp.float32)[..., None] * vt.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", rt.astype(jnp.float32),
                   sf + u.astype(jnp.float32)[None, :, :, None] * kv)
    s_new = wt.astype(jnp.float32)[..., None] * sf + kv
    return y.astype(rt.dtype), s_new
