"""Regression tests for the default UPD corpus (tsl_data/): the data layer the
whole generator runs on must stay present, schema-valid, and covering every
op the nn/train/data layers reach through repro.tsl_api.ops."""

from repro.core import loader
from repro.core.schema import PRIMITIVE_SCHEMA, TARGET_SCHEMA

# every op name the framework layers call via `from repro.tsl_api import ops`
FRAMEWORK_OPS = {
    "matmul", "embed_lookup", "cache_update", "rmsnorm", "layernorm",
    "softmax", "swiglu", "silu", "gelu", "sigmoid", "cross_entropy",
    "rope_apply", "flash_attention", "attention_decode", "token_shift",
    "causal_conv1d", "ssd_scan", "ssd_chunked", "ssd_decode", "wkv6_scan",
    "wkv6_decode", "topk_gating", "moe_dispatch", "moe_combine", "expert_ffn",
    # fused paged attention (ISSUE 9): the nn layer decodes/verifies straight
    # off the page pool through the block table
    "attention_decode_paged", "attention_verify_paged",
    # paper case-study surface (Fig 8) used by tests/benchmarks
    "set", "set1", "load", "select", "between_inclusive", "hadd",
    "to_integral", "range_count", "range_count_popcnt",
}


def _strip(doc):
    return {k: v for k, v in doc.items() if not k.startswith("__")}


def test_default_upd_targets_nonempty_and_valid():
    docs = loader.load_raw_targets()
    assert len(docs) >= 5
    names = set()
    for d in docs:
        enriched, errs, _ = TARGET_SCHEMA.apply(_strip(d))
        assert not errs, errs
        names.add(enriched["name"])
    assert {"cpu_xla", "gpu_pallas", "pallas_interpret", "pallas_tpu",
            "tpu_v5e"} <= names
    assert len(names) == len(docs), "duplicate target documents"


def test_default_upd_primitives_nonempty_and_valid():
    docs = loader.load_raw_primitives()
    assert len(docs) >= 25
    names = []
    for d in docs:
        enriched, errs, _ = PRIMITIVE_SCHEMA.apply(_strip(d))
        assert not errs, (d.get("primitive_name"), errs)
        assert enriched["definitions"], d.get("primitive_name")
        names.append(enriched["primitive_name"])
    assert len(set(names)) == len(names), "duplicate primitive documents"


def test_default_upd_covers_framework_ops():
    names = {d["primitive_name"] for d in loader.load_raw_primitives()}
    missing = FRAMEWORK_OPS - names
    assert not missing, f"UPD corpus missing framework ops: {sorted(missing)}"


def test_every_primitive_has_cpu_definition_and_test():
    """Every corpus primitive must be generatable for the portable target and
    carry at least one co-located test (paper §4.1 warns otherwise)."""
    for d in loader.load_raw_primitives():
        enriched, errs, _ = PRIMITIVE_SCHEMA.apply(_strip(d))
        assert not errs
        targets = set()
        for impl in enriched["definitions"]:
            t = impl["target_extension"]
            targets.update([t] if isinstance(t, str) else t)
        assert "cpu_xla" in targets, enriched["primitive_name"]
        assert enriched["testing"], enriched["primitive_name"]


def test_fingerprint_tracks_upd_content(tmp_path, monkeypatch):
    fp1 = loader.upd_fingerprint()
    extra = tmp_path / "upd"
    (extra / "targets").mkdir(parents=True)
    (extra / "targets" / "x.yaml").write_text("---\nname: x\n...\n")
    fp2 = loader.upd_fingerprint((str(extra),))
    assert fp1 != fp2
