"""State surgery for continuous batching: slot-level access to a live
batched decode state.

Every decode family carries its state as a pytree of arrays with the request
(slot) axis at a family-specific position per leaf — KV caches put it at
axis 1 under the layer axis, zamba's grouped SSM states at axis 2 under the
(group, layer-in-group) axes, rwkv recurrent states at axis 1, encdec
cross-state at axis 1. The family module declares that knowledge once as a
``state_batch_axes(state)`` pytree of ints (same treedef as the state), and
the surgery itself lives on the ModelApi: ``Model.insert_slot`` writes a
freshly prefilled single-request state (slot axis of size 1) into one slot,
``Model.reset_slot`` zeroes a finished slot. Both are pure jnp
(``dynamic_update_slice_in_dim`` with a traced slot index), so an engine can
jit them once and admit into ANY slot without recompiling — the
jit-stable-shape property per-step continuous batching depends on.

This module provides the serving-side companions: reading a slot back out
(``take_slot``) and host-side donor validation (``validate_donor``).
"""

from __future__ import annotations

import jax


def take_slot(state, axes, slot: int):
    """Read slot ``slot`` back out as a single-request state (host-side
    inspection / tests). Keeps the slot axis with size 1, mirroring what
    ``Model.insert_slot`` expects as a donor."""

    def tk(leaf, ax):
        return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=ax)

    return jax.tree.map(tk, state, axes)


def assert_span_fits(pos, span: int, state_len: int) -> None:
    """Raise RuntimeError if any slot's span write [pos, pos+span) would
    overrun the state's row capacity.

    ``jax.lax.dynamic_update_slice`` CLAMPS an out-of-range start index
    instead of erroring, so a verify slab launched too close to the end of
    the cache would silently slide backwards and rewrite the last committed
    rows — the worst kind of corruption, visible only as wrong tokens much
    later. The engine sizes its slot table with ``k_max`` headroom rows
    beyond max_len precisely so this never fires; this guard keeps the
    invariant loud if a future scheduling change breaks it."""
    import numpy as np

    pos = np.asarray(pos)
    hi = int(pos.max()) + int(span) if pos.size else 0
    if hi > state_len:
        raise RuntimeError(
            f"span write [{int(pos.max())}, {hi}) overruns the state's "
            f"{state_len} rows — dynamic_update_slice would clamp and "
            f"corrupt committed cache rows")


def validate_donor(state, donor, axes) -> None:
    """Raise ValueError unless ``donor`` is shape-compatible with one slot of
    ``state``: identical leaves except the slot axis, which must be 1.

    Catches the classic continuous-batching foot-guns before they become an
    XLA shape error deep in a jitted insert — e.g. a prefill that padded its
    KV cache to a different max_len than the engine's slot table, or an
    encdec donor whose encoder length differs from the engine's.
    """
    s_leaves, s_def = jax.tree.flatten(state)
    d_leaves, d_def = jax.tree.flatten(donor)
    a_leaves, _ = jax.tree.flatten(axes)
    if s_def != d_def:
        raise ValueError(
            f"donor state tree does not match batched state tree: "
            f"{d_def} vs {s_def}")
    for s, d, ax in zip(s_leaves, d_leaves, a_leaves):
        want = list(s.shape)
        want[ax] = 1
        if list(d.shape) != want:
            raise ValueError(
                f"donor leaf {d.shape} incompatible with batched leaf "
                f"{s.shape} (slot axis {ax}; expected {tuple(want)})")
