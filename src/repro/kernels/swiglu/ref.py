"""Pure-jnp oracle for the fused SwiGLU activation."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def swiglu(gate, up):
    """silu(gate) * up, computed in f32 and cast back."""
    g = gate.astype(jnp.float32)
    return (g * jax.nn.sigmoid(g) * up.astype(jnp.float32)).astype(gate.dtype)
