"""Continuous-batching serving subsystem.

- ``scheduler``: request queue, slot-table lifecycle, SLA accounting,
  ``lib.cost()``-driven admission (host-side control plane, no jax) — plus
  ``PagedAdmission``: page-count admission (admit on pages available now)
  with defer-not-refuse semantics and preemption bookkeeping;
- ``slots``: slot-level state access — read a slot back out, validate a
  donor against the slot table (the insert/reset surgery itself lives on
  ``Model.insert_slot``/``reset_slot``, uniform over all four families) —
  plus the paged-memory host primitives (``PageAllocator``, ``SlotPages``);
- ``paging``: the paged slot store — per-leaf row pools gathered/scattered
  through the ``cache_page_read/write`` UPD primitives, content-addressed
  copy-on-write prefix sharing, opt-in int8 pages;
- ``engine``: the per-step continuous-batching loop (jit-stable shapes,
  per-slot positions, TTFT / decode-t/s / SLA metrics); ``paged=`` switches
  residency from max-bucket lanes to page accounting with parking and
  preemption;
- ``spec``: speculative decoding — drafters (n-gram prompt-lookup / small
  draft model), the longest-accepted-prefix rule, and UPD-cost-priced
  per-slot speculation depth (``attention_verify``'s serve block + cost
  terms drive both the span bound and the depth decision).

See README.md in this directory for the slot/state-surgery contract.
"""

from .engine import SamplingConfig, ServeEngine
from .paging import (PagedConfig, PagedKVStore, PrefixStore, prefix_key,
                     selected_page_size, upd_page_defaults)
from .scheduler import (BucketPolicy, CostModelAdmission, PagedAdmission,
                        Request, RequestMetrics, Scheduler,
                        upd_serve_defaults)
from .slots import (PageAllocator, PagesExhausted, SlotPages,
                    assert_span_fits, take_slot, validate_donor)
from .spec import (DraftModelDrafter, NGramDrafter, SpeculationConfig,
                   SpeculationPolicy, accept_span, upd_verify_defaults)

__all__ = [
    "BucketPolicy",
    "CostModelAdmission",
    "DraftModelDrafter",
    "NGramDrafter",
    "PageAllocator",
    "PagedAdmission",
    "PagedConfig",
    "PagedKVStore",
    "PagesExhausted",
    "PrefixStore",
    "Request",
    "RequestMetrics",
    "SamplingConfig",
    "Scheduler",
    "ServeEngine",
    "SlotPages",
    "SpeculationConfig",
    "SpeculationPolicy",
    "accept_span",
    "assert_span_fits",
    "prefix_key",
    "selected_page_size",
    "take_slot",
    "upd_page_defaults",
    "upd_serve_defaults",
    "upd_verify_defaults",
    "validate_donor",
]
