"""Training launcher: fault-tolerant loop with checkpoint/restart, straggler
watchdog, and mesh-aware sharding.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Restart semantics: on start, restore the newest committed checkpoint (params,
optimizer, data-pipeline state) and continue; kill -9 at any point loses at
most `ckpt_every` steps. The watchdog flags steps slower than
``straggler_factor`` x the running median — on a real pod this feeds the
controller that evicts/replaces the slow host; here it logs and counts.
"""

from __future__ import annotations

import argparse
import json
import statistics
import time

import jax

from repro.ckpt.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataState, Prefetcher, SyntheticTokens
from repro.dist import sharding
from repro.launch.mesh import make_host_mesh
from repro.nn.model import build_model
from repro.train.optimizer import OptConfig
from repro.train.step import init_train_state, make_train_step


class StepWatchdog:
    """Straggler mitigation, single-host flavor: detect slow steps, attribute
    them (data-starved vs compute), and surface counters for the controller."""

    def __init__(self, factor: float = 3.0, window: int = 50):
        self.factor = factor
        self.times: list[float] = []
        self.window = window
        self.stragglers = 0
        self.data_starved = 0

    def observe(self, dt: float, queue_depth: int) -> bool:
        self.times.append(dt)
        self.times = self.times[-self.window:]
        if len(self.times) >= 5:
            med = statistics.median(self.times)
            if dt > self.factor * med:
                self.stragglers += 1
                if queue_depth == 0:
                    self.data_starved += 1
                return True
        return False


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moment-dtype", default="float32", choices=["float32", "int8"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    opt_cfg = OptConfig(lr=args.lr, total_steps=max(args.steps, 10),
                        warmup_steps=max(args.steps // 10, 2),
                        moment_dtype=args.moment_dtype)

    mesh = make_host_mesh(args.dp, args.tp)
    train_step = make_train_step(model, opt_cfg, microbatches=args.microbatches,
                                 compress_grads=args.compress_grads, mesh=mesh)

    key = jax.random.PRNGKey(args.seed)
    with mesh:
        state = init_train_state(model, opt_cfg, key, mesh=mesh)

    data_state = DataState()
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt is not None:
        restored = ckpt.restore_latest(state)
        if restored is not None:
            start_step, state, extra = restored
            data_state = DataState.from_dict(extra.get("data", {}))
            print(f"[train] restored checkpoint at step {start_step}")

    source = SyntheticTokens(cfg.vocab, args.batch, args.seq, seed=args.seed)
    data_state.step = start_step
    prefetch = Prefetcher(source, data_state, depth=2)
    watchdog = StepWatchdog()

    jstep = jax.jit(train_step, donate_argnums=(0,))
    losses = []
    t_start = time.perf_counter()
    with mesh:
        for step in range(start_step, args.steps):
            t0 = time.perf_counter()
            batch = prefetch.get()
            batch = jax.device_put(batch, sharding.batch_shardings(mesh, batch))
            state, metrics = jstep(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            if watchdog.observe(dt, prefetch.depth):
                print(f"[watchdog] step {step}: straggler ({dt:.2f}s, "
                      f"queue={prefetch.depth})")
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} ({dt:.2f}s)")
            if ckpt is not None and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state,
                          extra={"data": data_state.as_dict()})
    if ckpt is not None:
        ckpt.save(args.steps, state, extra={"data": data_state.as_dict()},
                  async_=False)
        ckpt.wait()
    prefetch.stop()

    wall = time.perf_counter() - t_start
    tokens = (args.steps - start_step) * args.batch * args.seq
    result = {
        "arch": cfg.name,
        "first_loss": losses[0] if losses else None,
        "final_loss": losses[-1] if losses else None,
        "steps": args.steps,
        "tokens_per_s": tokens / wall,
        "stragglers": watchdog.stragglers,
    }
    print("[train] done:", json.dumps(result))
    return result


if __name__ == "__main__":
    main()
