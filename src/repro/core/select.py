"""Implementation-selection GPO (paper Fig 5 ②).

*"We implemented a heuristic model, which finds the highest match between the
required hardware capabilities of the user given implementation and the
actually available hardware features. The underlying idea is that if an
implementation uses more hardware-provided functionalities, the implementation
[...] is more specialized against the underlying hardware. If multiple variants
with the same similarity score exist, the implementations are sorted ascending
by the number of lines of code, and the first (i.e. shortest) implementation
is chosen."*

Also performs the *relevance filter*: only primitives/definitions for the
requested target (and the cherry-picked ``only`` subset plus transitive test
dependencies) survive — paper: "we can generate the complete library or only a
slim one on a per-use-case basis".
"""

from __future__ import annotations

from .model import GenerationResult, ImplDef, PrimitiveDef, Selection


def hardware_flags(ctx: GenerationResult) -> frozenset[str]:
    """Available feature flags: target SRU flags, optionally overridden by the
    user-supplied hardware description (paper: flags may be user input or
    probed from the OS)."""
    tgt = ctx.targets[ctx.config.target]
    if ctx.config.hardware_flags is not None:
        return frozenset(ctx.config.hardware_flags)
    return frozenset(tgt.flags)


def valid_candidates(prim: PrimitiveDef, target: str, ctype: str,
                     hw: frozenset[str]) -> list[ImplDef]:
    """Definitions that are well-formed on this hardware: right target, right
    ctype, and *all* required flags available."""
    return [
        d
        for d in prim.definitions
        if d.target_extension == target
        and ctype in d.ctypes
        and frozenset(d.flags) <= hw
    ]


def score(impl: ImplDef, hw: frozenset[str]) -> int:
    """Similarity score = number of hardware capabilities the implementation
    exercises (all of them are available, by candidate validity)."""
    return len(frozenset(impl.flags) & hw)


def choose(prim: PrimitiveDef, target: str, ctype: str, hw: frozenset[str]
           ) -> Selection | None:
    cands = valid_candidates(prim, target, ctype, hw)
    if not cands:
        return None
    ranked = sorted(
        cands,
        key=lambda d: (-score(d, hw), d.loc, prim.definitions.index(d)),
    )
    best = ranked[0]
    return Selection(
        primitive=prim.name,
        target=target,
        ctype=ctype,
        impl=best,
        score=score(best, hw),
        candidates=len(cands),
        reason="flags",
    )


def cherry_pick(ctx: GenerationResult) -> set[str]:
    """Resolve the ``only`` subset, closing over test dependencies so that the
    generated slim library still carries everything its tests need."""
    if ctx.config.only is None:
        return set(ctx.primitives)
    want = set(ctx.config.only)
    unknown = want - set(ctx.primitives)
    for u in sorted(unknown):
        ctx.fail(f"cherry-pick: unknown primitive {u!r}")
    frontier = list(want & set(ctx.primitives))
    seen = set(frontier)
    while frontier:
        p = frontier.pop()
        for t in ctx.primitives[p].tests:
            for dep in t.requires:
                if dep in ctx.primitives and dep not in seen:
                    seen.add(dep)
                    frontier.append(dep)
    return seen


class SelectGPO:
    name = "select"

    def run(self, ctx: GenerationResult) -> GenerationResult:
        target = ctx.config.target
        if target not in ctx.targets:
            ctx.fail(f"select: unknown target {target!r}")
            return ctx
        hw = hardware_flags(ctx)
        keep = cherry_pick(ctx)
        tgt = ctx.targets[target]
        for name in sorted(keep):
            prim = ctx.primitives[name]
            per_ctype: dict[str, Selection] = {}
            for ctype in tgt.ctypes:
                sel = choose(prim, target, ctype, hw)
                if sel is not None:
                    per_ctype[ctype] = sel
                    if not sel.impl.is_native:
                        # paper §3.2: non-native workaround -> build-time warning
                        ctx.warn(
                            f"primitive {name!r} [{target}/{ctype}]: selected "
                            f"implementation is a non-native workaround"
                        )
            if per_ctype:
                ctx.selection[name] = per_ctype
            else:
                ctx.warn(
                    f"primitive {name!r}: no valid implementation for target "
                    f"{target!r} — omitted from the generated library"
                )
        ctx.meta["hardware_flags"] = sorted(hw)
        return ctx
