"""Flash-attention backward: dedicated recomputation dq/dk/dv Pallas kernels
(interpret mode on CPU) vs the jnp oracle VJP, plus the memory contract —
the custom_vjp saves only O(Sq)-per-head residuals, never the (Sq, Sk)
attention matrix (ISSUE 3 acceptance criteria)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(7)


def _arr(shape, dt="float32", lo=-1, hi=1):
    return jnp.asarray(RNG.uniform(lo, hi, shape), dtype=dt)


def _tol(dt):
    return dict(rtol=5e-2, atol=5e-2) if dt == "bfloat16" \
        else dict(rtol=2e-3, atol=2e-3)


def _oracle_grads(q, k, v, g, **kw):
    from repro.kernels.flash_attention import ref

    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention(q_, k_, v_, **kw), q, k, v)
    return vjp(g)


@pytest.mark.parametrize("b,h,kh,sq,sk,d,causal,kv_len", [
    (1, 2, 2, 64, 64, 32, True, None),     # block-multiple causal
    (2, 4, 2, 96, 160, 32, True, None),    # GQA + non-multiple Sq/Sk + padding
    (1, 2, 1, 64, 64, 16, False, None),    # MQA non-causal
    (1, 8, 4, 200, 72, 16, True, None),    # sq > sk (fully-masked early rows)
    (1, 2, 2, 40, 64, 16, False, 48),      # kv_len-masked cache tail
    (1, 4, 2, 1, 64, 32, True, 40),        # decode shape: sq=1, kv_len < Sk
    (1, 2, 2, 16, 64, 16, True, 40),       # prefill continuation: causal AND
                                           #   kv_len < Sk with sq > 1
])
@pytest.mark.parametrize("dt", ["float32", "bfloat16"])
def test_flash_attention_grads_match_oracle(b, h, kh, sq, sk, d, causal,
                                            kv_len, dt):
    from repro.kernels.flash_attention import ops

    q = _arr((b, h, sq, d), dt)
    k = _arr((b, kh, sk, d), dt)
    v = _arr((b, kh, sk, d), dt)
    g = _arr((b, h, sq, d), dt)

    def f(q_, k_, v_):
        return ops.flash_attention(q_, k_, v_, causal=causal, kv_len=kv_len,
                                   block_q=32, block_k=64, interpret=True)

    _, vjp = jax.vjp(f, q, k, v)
    got = vjp(g)
    want = _oracle_grads(q, k, v, g, causal=causal, kv_len=kv_len)
    for name, a, b_ in zip(("dq", "dk", "dv"), got, want):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   err_msg=name, **_tol(dt))


def test_causal_kv_len_alignment_agrees_across_dialects():
    """Prefill continuation (causal=True, kv_len < Sk, sq > 1): the jnp
    oracle, the chunked jnp variant, and the Pallas kernel must share the
    ends-at-kv_len causal alignment — otherwise the flash_attention_bwd
    primitive returns different gradients on cpu_xla vs pallas targets."""
    from repro.kernels.flash_attention import ops, ref

    q = _arr((1, 2, 16, 16))
    k, v = _arr((1, 2, 64, 16)), _arr((1, 2, 64, 16))
    kw = dict(causal=True, kv_len=40)
    a = ref.attention(q, k, v, **kw)
    b = ref.attention_chunked(q, k, v, block_k=32, **kw)
    c = ops.flash_attention(q, k, v, block_q=8, block_k=32, interpret=True,
                            **kw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_vjp_standalone_entry():
    """The UPD flash_attention_bwd primitive calls flash_attention_vjp
    directly — same contract as differentiating through flash_attention."""
    from repro.kernels.flash_attention import ops

    q, g = _arr((1, 4, 40, 16)), _arr((1, 4, 40, 16))
    k, v = _arr((1, 2, 56, 16)), _arr((1, 2, 56, 16))
    got = ops.flash_attention_vjp(q, k, v, g, causal=True, block_q=32,
                                  block_k=32, interpret=True)
    want = _oracle_grads(q, k, v, g, causal=True)
    for name, a, b_ in zip(("dq", "dk", "dv"), got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   err_msg=name, **_tol("float32"))


def test_fwd_residuals_are_linear_in_sequence():
    """The residuals saved by _fa_fwd are O(Sq) per head: the three inputs,
    the output, and a (B, H, Sq) logsumexp — no S×S tensor (the oracle-VJP
    fallback this kernel replaced materialized exp-scores of (Sq, Sk))."""
    from repro.kernels.flash_attention import ops

    b, h, sq, sk, d = 1, 2, 64, 192, 16   # sq != sk disambiguates axes
    q = _arr((b, h, sq, d))
    k, v = _arr((b, h, sk, d)), _arr((b, h, sk, d))
    out, res = ops._fa_fwd(True, None, sk, 32, 64, True, q, k, v)
    assert out.shape == q.shape
    expected = {q.shape, k.shape, (b, h, sq), }
    for leaf in res:
        assert tuple(leaf.shape[-2:]) != (sq, sk), \
            f"S×S residual materialized: {leaf.shape}"
        assert leaf.shape in expected, leaf.shape
    # total residual bytes are linear in sequence length: well under one
    # f32 (Sq, Sk) score matrix per head
    res_bytes = sum(x.size * x.dtype.itemsize for x in res)
    assert res_bytes < 4 * b * h * sq * sk


def test_fwd_logsumexp_residual_values():
    """lse must equal log-sum-exp of the masked scaled scores row-wise —
    the backward recomputes p = exp(s - lse) from it."""
    from repro.kernels.flash_attention import kernel

    b, h, s, d = 1, 2, 64, 16
    q, k, v = _arr((b, h, s, d)), _arr((b, h, s, d)), _arr((b, h, s, d))
    out, lse = kernel.flash_attention_fwd_4d(q, k, v, causal=True,
                                             block_q=32, block_k=32,
                                             interpret=True)
    sc = 1.0 / (d ** 0.5)
    sm = np.einsum("bhqd,bhkd->bhqk", np.asarray(q, np.float32),
                   np.asarray(k, np.float32)) * sc
    mask = np.tril(np.ones((s, s), bool))
    sm = np.where(mask, sm, -np.inf)
    want = np.log(np.exp(sm - sm.max(-1, keepdims=True)).sum(-1)) \
        + sm.max(-1, keepdims=True)[..., 0]
    np.testing.assert_allclose(np.asarray(lse), want, rtol=1e-5, atol=1e-5)


def test_generated_tsl_trains_through_pallas_backward():
    """End-to-end through the generated pallas_interpret TSL: grad of a loss
    over ops.flash_attention runs the Pallas backward kernels and matches the
    oracle — the training path no longer relies on the jnp-oracle VJP."""
    from repro.core import load_library
    from repro.kernels.flash_attention import ref

    lib = load_library("pallas_interpret")
    q = _arr((1, 4, 32, 16))
    k, v = _arr((1, 2, 32, 16)), _arr((1, 2, 32, 16))

    def loss_tsl(q_):
        return jnp.sum(lib.ops.flash_attention(q_, k, v, causal=True) ** 2)

    def loss_ref(q_):
        return jnp.sum(ref.attention(q_, k, v, causal=True) ** 2)

    g1 = jax.grad(loss_tsl)(q)
    g2 = jax.grad(loss_ref)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=2e-3, atol=2e-3)
