"""arctic-480b [moe]: 128 experts top-2 + dense residual.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base; hf]

Arctic's dense-MoE hybrid: a small dense MLP runs in parallel (residual) with
the 128-expert MoE FFN; we model the dense residual width as d_model.
"""

from .arch import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    head_dim=128,
    n_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
    dense_residual_ff=7168,
    capacity_factor=1.25,
    rope_theta=1e4,
    source="hf:Snowflake/snowflake-arctic-base; hf",
)
