"""Benchmark utilities: wall-clock timing of jitted callables on the host."""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, n_iter: int = 20, warmup: int = 3, **kw) -> float:
    """Median-of-runs microsecond timing for a jitted callable."""
    for _ in range(warmup):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    times = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6      # median, microseconds


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
