"""Paged slot memory tests (ISSUE 8).

Covers: hypothesis property tests for the page allocator (alloc/retain/
release round trips, refcounts never negative, double-free rejected) and the
prefix store (store-held pages stay referenced, eviction only without live
sharers); copy-on-write isolation (a sharer can never mutate a shared page);
full-precision store round trips bit-exactly and int8 honours the absmax
error bound; page-count admission (``PagedAdmission`` against a fake budget,
defer-not-refuse requeue semantics — the satellite-1 scheduler unit test);
and the tentpole pin: paged vs. contiguous decode is token-for-token
identical on all four decode families, greedy AND sampled, including
mid-stream slot reuse (more requests than lanes -> park + reactivate),
prefix sharing with the prefill-once chunk count, and preemption under a
tiny page budget. Plus the zero-core-diff structural proof for the two
cache_page primitives.
"""

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs import get_config
from repro.serve import (PagedAdmission, PagedConfig, PagedKVStore,
                         PageAllocator, PagesExhausted, Request, SamplingConfig,
                         Scheduler, ServeEngine, prefix_key)


def _requests(cfg, gen_lens, prompt_len=8, seed=0, stagger=0.0, prefix=None):
    rng = np.random.default_rng(seed)
    out = []
    for i, g in enumerate(gen_lens):
        toks = rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)
        if prefix is not None:
            toks[:len(prefix)] = prefix
        out.append(Request(rid=f"r{i}", tokens=toks, gen_len=g,
                           arrival_s=i * stagger,
                           shared_prefix_len=len(prefix) if prefix is not None
                           else None))
    return out


# -- page allocator properties -------------------------------------------------


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 12),
       st.lists(st.tuples(st.integers(0, 2), st.integers(0, 10**6)),
                max_size=120))
def test_allocator_refcounts_and_round_trip(n_pages, ops):
    """Under any alloc/retain/release interleaving the allocator agrees with
    a shadow refcount model: free + held partitions the pool, refcounts
    match exactly (so they can never go negative), exhaustion raises, and
    releasing every reference returns the pool to fully free."""
    alloc = PageAllocator(n_pages)
    held: dict[int, int] = {}              # page -> our refcount
    for op, pick in ops:
        if op == 0:                         # alloc
            if alloc.free_pages == 0:
                with pytest.raises(PagesExhausted):
                    alloc.alloc()
            else:
                p = alloc.alloc()
                assert p not in held
                held[p] = 1
        elif op == 1 and held:              # retain a held page
            p = sorted(held)[pick % len(held)]
            alloc.retain(p)
            held[p] += 1
        elif op == 2 and held:              # release one reference
            p = sorted(held)[pick % len(held)]
            alloc.release(p)
            held[p] -= 1
            if held[p] == 0:
                del held[p]
        assert alloc.free_pages == n_pages - len(held)
        assert alloc.used_pages == len(held)
        for p, c in held.items():
            assert alloc.refcount(p) == c
    for p in sorted(held):
        for _ in range(held[p]):
            alloc.release(p)
    assert alloc.free_pages == n_pages


def test_allocator_double_free_and_stale_retain_raise():
    alloc = PageAllocator(2)
    p = alloc.alloc()
    alloc.release(p)
    with pytest.raises(ValueError):
        alloc.release(p)                    # double free
    with pytest.raises(ValueError):
        alloc.retain(p)                     # retain after free
    with pytest.raises(ValueError):
        alloc.release(99)                   # never allocated


# -- prefix store properties ---------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 4)),
                max_size=60))
def test_prefix_store_refcount_invariants(ops):
    """Random publish/lookup/release/evict sequences: every store entry's
    pages stay referenced (refcount >= 1), eviction only removes entries
    with no live sharer, and sharer releases never underflow — mirrored
    against a shadow model of outstanding lookup references."""
    from repro.serve import PrefixStore

    alloc = PageAllocator(16)
    store = PrefixStore(alloc)
    sharer_refs: list[tuple[str, list[int]]] = []   # outstanding lookups
    n_published = 0
    for op, pick in ops:
        if op == 0 and alloc.free_pages >= 2:       # publish a fresh entry
            pages = [alloc.alloc(), alloc.alloc()]
            key = f"k{n_published}"
            n_published += 1
            assert store.publish(key, pages, n_rows=2, tail=None)
            # the publisher's own working references are dropped on free
            for p in pages:
                alloc.release(p)
        elif op == 1 and store.entries:             # lookup retains
            key = sorted(store.entries)[pick % len(store.entries)]
            entry = store.lookup(key)
            assert entry is not None
            sharer_refs.append((key, list(entry.pages)))
        elif op == 2 and sharer_refs:               # a sharer finishes
            _, pages = sharer_refs.pop(pick % len(sharer_refs))
            for p in pages:
                alloc.release(p)
        elif op == 3:                               # evict LRU if possible
            live = {k for k, _ in sharer_refs}
            evictable = set(store.evictable())
            assert not (evictable & live)
            store.evict_one()
        for e in store.entries.values():
            for p in e.pages:
                assert alloc.refcount(p) >= 1
    # drain: every sharer done + every entry evicted -> pool fully free
    for _, pages in sharer_refs:
        for p in pages:
            alloc.release(p)
    while store.evict_one():
        pass
    assert not store.entries and alloc.free_pages == 16


def test_publish_is_idempotent_prefill_once():
    from repro.serve import PrefixStore

    alloc = PageAllocator(4)
    store = PrefixStore(alloc)
    p = [alloc.alloc()]
    assert store.publish("k", p, n_rows=1, tail=None)
    assert not store.publish("k", p, n_rows=1, tail=None)   # no double retain
    assert alloc.refcount(p[0]) == 2


# -- the paged store: round trip, CoW, int8 ------------------------------------


def _mini_store(**kw):
    import jax

    shapes = {"k": jax.ShapeDtypeStruct((1, 32, 4), np.float32)}
    return PagedKVStore(shapes, {"k": 1}, **kw)


def _donor(rows=32, seed=0):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    return {"k": jnp.asarray(rng.standard_normal((1, rows, 4)), jnp.float32)}


def test_store_round_trip_is_bit_exact():
    store = _mini_store(page_size=8, n_pages=8)
    donor = _donor(seed=1)
    store.attach("r", prompt_rows=20)
    store.store_donor("r", donor, fill=20)
    out = store.load_donor("r", {"k": np.zeros((1, 32, 4), np.float32)})
    np.testing.assert_array_equal(np.asarray(out["k"])[:, :20],
                                  np.asarray(donor["k"])[:, :20])
    store.free("r")
    assert store.allocator.free_pages == store.n_pages


def test_cow_never_mutates_a_shared_page():
    """r2 shares r1's published prefix, then writes INTO the shared page
    range: the write must land on a fresh copy (cow_copies == 1) and r1's
    view of the prefix must be byte-identical before and after."""
    store = _mini_store(page_size=8, n_pages=12)
    d1 = _donor(seed=1)
    store.attach("r1", prompt_rows=16)
    store.store_donor("r1", d1, fill=16)
    key = "shared"
    store.publish_prefix("r1", key, n_rows=16, tail=None)

    shared = store.attach("r2", prompt_rows=16, share_key=key)
    assert shared == 16
    sp1, sp2 = store.requests["r1"], store.requests["r2"]
    assert sp1.pages[:2] == sp2.pages[:2]            # physically shared

    import jax.numpy as jnp

    store.write_rows("r2", 8, 16,
                     {"k": jnp.full((8, 1, 4), 7.0, jnp.float32)})
    assert store.cow_copies == 1
    assert sp1.pages[1] != store.requests["r2"].pages[1]   # diverged
    r1 = store.load_donor("r1", {"k": np.zeros((1, 32, 4), np.float32)})
    np.testing.assert_array_equal(np.asarray(r1["k"])[:, :16],
                                  np.asarray(d1["k"])[:, :16])
    r2 = store.load_donor("r2", {"k": np.zeros((1, 32, 4), np.float32)})
    np.testing.assert_array_equal(np.asarray(r2["k"])[:, 8:16],
                                  np.full((1, 8, 4), 7.0, np.float32))


def test_attach_rollback_on_exhaustion():
    store = _mini_store(page_size=8, n_pages=2)
    store.attach("r1", prompt_rows=16)               # takes both pages
    free_before = store.allocator.free_pages
    with pytest.raises(PagesExhausted):
        store.attach("r2", prompt_rows=8)
    assert store.allocator.free_pages == free_before
    assert "r2" not in store.requests


def test_int8_pages_honour_the_absmax_bound():
    """int8 pages round-trip within the wire format's bound: per last-axis
    row, |x - deq(q)| <= absmax / 254 (+ float slack)."""
    store = _mini_store(page_size=8, n_pages=8, int8=True)
    donor = _donor(seed=3)
    store.attach("r", prompt_rows=24)
    store.store_donor("r", donor, fill=24)
    out = store.load_donor("r", {"k": np.zeros((1, 32, 4), np.float32)})
    x = np.asarray(donor["k"])[:, :24]
    y = np.asarray(out["k"])[:, :24]
    bound = np.abs(x).max(axis=-1, keepdims=True) / 254 + 1e-6
    assert (np.abs(x - y) <= bound).all()


# -- page-count admission (satellite 1) ----------------------------------------


class FakeBudget:
    def __init__(self, free):
        self.free = free

    def pages_for_rows(self, rows):
        return -(-rows // 8) + 1            # data pages + tail reservation

    def pages_free(self):
        return self.free


def test_paged_admission_defers_on_page_shortage():
    cfg = get_config("qwen1.5-0.5b").reduced()
    budget = FakeBudget(free=100)
    adm = PagedAdmission(cfg, batch=2, max_len=64, budget=budget)
    req = Request(rid="a", tokens=np.zeros(16, np.int32), gen_len=4)
    ok, _ = adm.admit(req, 0.0)
    assert ok
    budget.free = 1                          # 16 rows need 2+1 pages
    req2 = Request(rid="b", tokens=np.zeros(16, np.int32), gen_len=4)
    ok, reason = adm.admit(req2, 0.0)
    assert not ok and reason.startswith("defer")

    # defer requeues at the FRONT; permanent refusals do not
    sched = Scheduler(2, adm)
    sched.submit(req2, 0.0)
    assert sched.next_admissible(0.0) is None
    assert sched.queue and sched.queue[0].rid == "b"   # still queued, front
    assert not sched.refused


def test_paged_admission_continuation_skips_sla_but_pays_pages():
    cfg = get_config("qwen1.5-0.5b").reduced()
    budget = FakeBudget(free=100)
    adm = PagedAdmission(cfg, batch=2, max_len=64, budget=budget)
    # an SLA no fresh request could meet: the continuation skips that check
    cont = Request(rid="c", tokens=np.zeros(24, np.int32), gen_len=4,
                   sla_s=1e-9, resume_token=7)
    ok, _ = adm.admit(cont, 0.0)
    assert ok and cont.bucket >= 24
    budget.free = 0                          # ...but never the page check
    cont2 = Request(rid="d", tokens=np.zeros(24, np.int32), gen_len=4,
                    resume_token=7)
    ok, reason = adm.admit(cont2, 0.0)
    assert not ok and reason.startswith("defer")
    # a continuation that cannot re-prefill within max_len is refused for real
    huge = Request(rid="e", tokens=np.zeros(64, np.int32), gen_len=4,
                   resume_token=7)
    ok, reason = adm.admit(huge, 0.0)
    assert not ok and reason.startswith("over_budget")


# -- tentpole pin: paged == contiguous, all four families ----------------------


@pytest.mark.parametrize("arch,enc_len", [("qwen1.5-0.5b", None),
                                          ("rwkv6-7b", None),
                                          ("zamba2-7b", None),
                                          ("whisper-tiny", 8),
                                          ("internvl2-2b", None)])
def test_paged_decode_matches_contiguous_all_families(arch, enc_len):
    """4 staggered requests on 2 lanes, greedy: the paged engine (park +
    reactivate through the page pools, mid-stream slot reuse) must emit
    exactly the contiguous engine's tokens, while holding more requests
    resident than it has lanes."""
    import jax

    jax.clear_caches()
    cfg = get_config(arch).reduced()
    max_len = 24 + (cfg.vision_prefix if cfg.family == "vlm" else 0)
    reqs = _requests(cfg, [5, 4, 4, 3], stagger=0.05)
    if cfg.family == "vlm":
        for r in reqs:
            r.embeds = np.ones((cfg.vision_prefix, cfg.d_model), np.float32)
    if cfg.family == "audio":
        for r in reqs:
            r.embeds = np.ones((enc_len, cfg.d_model), np.float32)
    want = ServeEngine(cfg, batch=2, max_len=max_len, seed=0,
                       enc_len=enc_len).run(
        [Request(**vars(r)) for r in reqs])

    jax.clear_caches()
    got = ServeEngine(cfg, batch=2, max_len=max_len, seed=0, enc_len=enc_len,
                      paged=PagedConfig()).run(
        [Request(**vars(r)) for r in reqs])
    assert got["outputs"] == want["outputs"]
    assert got["paged"]["resident_requests_peak"] > 2   # exceeded the lanes
    assert got["paged"]["hbm_bytes_resident"] == 0      # all freed at the end


def test_paged_sampled_matches_contiguous():
    """Sampled decoding (temperature + top-k) draws from the SAME per-step
    key sequence when every request fits a lane and prefix sharing is off —
    paged residency must not change a single draw."""
    import jax

    cfg = get_config("qwen1.5-0.5b").reduced()
    samp = SamplingConfig(temperature=0.8, top_k=16)
    reqs = _requests(cfg, [6, 5], seed=2)
    jax.clear_caches()
    want = ServeEngine(cfg, batch=2, max_len=24, seed=0, sampling=samp).run(
        [Request(**vars(r)) for r in reqs])
    jax.clear_caches()
    got = ServeEngine(cfg, batch=2, max_len=24, seed=0, sampling=samp,
                      paged=PagedConfig(prefix_sharing=False)).run(
        [Request(**vars(r)) for r in reqs])
    assert got["outputs"] == want["outputs"]


def test_prefix_sharing_prefills_shared_prompt_once():
    """4 requests sharing a 16-token system prompt, page_size 16: one miss,
    three hits, and the chunk count proves the prefix ran ONCE — 16/4 = 4
    chunks for the publisher plus (24-16)/4 = 2 per sharer. Outputs still
    match the contiguous engine exactly."""
    import jax

    cfg = get_config("qwen1.5-0.5b").reduced()
    rng = np.random.default_rng(7)
    system = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    reqs = _requests(cfg, [3, 3, 3, 3], prompt_len=24, seed=8,
                     stagger=0.05, prefix=system)
    jax.clear_caches()
    want = ServeEngine(cfg, batch=2, max_len=48, seed=0).run(
        [Request(**vars(r)) for r in reqs])
    jax.clear_caches()
    eng = ServeEngine(cfg, batch=2, max_len=48, seed=0,
                      paged=PagedConfig(page_size=16))
    rep = eng.run([Request(**vars(r)) for r in reqs])
    assert rep["outputs"] == want["outputs"]
    assert rep["paged"]["prefix_hits"] == 3
    assert rep["paged"]["prefix_misses"] == 1
    chunk = eng.policy.chunk
    bucket = rep["per_request"][0]["bucket"]      # same prompt len -> same
    chunks = sum(e["chunks"] for e in rep["step_log"])
    # publisher runs its whole bucket; each sharer skips the 16 shared rows
    assert chunks == bucket // chunk + 3 * ((bucket - 16) // chunk)


def test_preemption_returns_exact_tokens():
    """A page pool too small for three concurrent requests forces at least
    one preemption; the preempted request re-prefills its history as a
    continuation and must still emit exactly the contiguous tokens."""
    import jax

    cfg = get_config("qwen1.5-0.5b").reduced()
    # gen 10 on prompt 8 grows each request from 1 page to 3 (page 8):
    # admission prices the PROMPT, so growth is what exhausts the 5-page
    # pool and triggers preemption
    reqs = _requests(cfg, [10, 10, 10], stagger=0.05, seed=5)
    jax.clear_caches()
    want = ServeEngine(cfg, batch=2, max_len=24, seed=0).run(
        [Request(**vars(r)) for r in reqs])

    jax.clear_caches()
    probe = ServeEngine(cfg, batch=2, max_len=24, seed=0,
                        paged=PagedConfig(page_size=8))
    budget = 5 * probe._store.page_bytes
    jax.clear_caches()
    eng = ServeEngine(cfg, batch=2, max_len=24, seed=0,
                      paged=PagedConfig(page_size=8,
                                        hbm_budget_bytes=budget))
    rep = eng.run([Request(**vars(r)) for r in reqs])
    assert rep["outputs"] == want["outputs"]
    assert rep["paged"]["preemptions"] >= 1
    assert any(e["preemptions"] >= 1 for e in rep["per_request"])


def test_int8_paged_engine_smoke():
    """int8 pages change numerics (documented), so no exactness pin — but
    every request must finish with the right token count and the report
    must flag the precision."""
    import jax

    cfg = get_config("qwen1.5-0.5b").reduced()
    reqs = _requests(cfg, [4, 4, 4], stagger=0.05)
    jax.clear_caches()
    rep = ServeEngine(cfg, batch=2, max_len=24, seed=0,
                      paged=PagedConfig(int8=True)).run(reqs)
    assert rep["paged"]["int8"]
    assert {r.rid: len(rep["outputs"][r.rid]) for r in reqs} == \
        {r.rid: r.gen_len for r in reqs}


# -- structural: the primitives are pure UPD data ------------------------------


def test_cache_page_primitives_zero_core_diff():
    """No file under core/ knows the paged-memory primitives exist — they
    are data (tsl_data/primitives/memory.yaml), same proof as gpu_pallas."""
    from pathlib import Path

    import repro.core

    core_dir = Path(repro.core.__file__).parent
    offenders = [f.name for f in sorted(core_dir.rglob("*"))
                 if f.suffix in (".py", ".j2") and f.is_file()
                 and "cache_page" in f.read_text()]
    assert not offenders, offenders


def test_cache_page_primitives_cover_every_target():
    from repro.core import load_corpus

    corpus = load_corpus()
    for name in ("cache_page_read", "cache_page_write"):
        prim = corpus.primitives[name]
        covered = {d.target_extension for d in prim.definitions}
        assert covered == set(corpus.targets), (name, covered)
        assert prim.tests, name


def test_prefix_key_is_content_addressed():
    base = dict(arch="qwen", page_size=16, int8=False, seed=0,
                prefix_rows=0, tokens=[1, 2, 3])
    k = prefix_key(**base)
    assert k == prefix_key(**base)
    assert k != prefix_key(**{**base, "tokens": [1, 2, 4]})
    assert k != prefix_key(**{**base, "int8": True})
    assert k != prefix_key(**{**base, "seed": 1})
