"""Mixture-of-Experts layer (grok/arctic) on TSL moe primitives.

Capacity-based dispatch (static shapes), batched expert einsum, optional
dense residual branch (arctic). Aux load-balancing loss (Switch-style)
returned alongside the output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.tsl_api import ops as tsl

from .common import dense_init, split_keys
from .mlp import init_mlp, mlp_forward


def init_moe(key, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), dtype),
        "w_gate": dense_init(ks[1], (e, d, ff), dtype),
        "w_up": dense_init(ks[2], (e, d, ff), dtype),
        "w_down": dense_init(ks[3], (e, ff, d), dtype),
    }
    if cfg.moe_dense_residual:
        p["dense"] = init_mlp(ks[4], cfg, dtype, d_ff=cfg.dense_residual_ff or d)
    return p


def capacity_for(cfg, tokens: int) -> int:
    cap = int(tokens * cfg.experts_per_token * cfg.capacity_factor
              / cfg.n_experts)
    return max(8, cap)


def moe_forward(p, x, cfg):
    """x: (B,S,D) -> (y, aux_loss)."""
    from repro.dist.sharding import logical_constraint

    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    logits = tsl.matmul(x2, p["router"])
    weights, idx = tsl.topk_gating(logits, k=cfg.experts_per_token)
    cap = capacity_for(cfg, b * s)
    xe, info = tsl.moe_dispatch(x2, idx, weights, n_experts=cfg.n_experts,
                                capacity=cap)
    # pin the expert-batch layout — without this GSPMD is free to replicate
    # the (E, C, d) dispatch tensor across the mesh (§Perf grok iteration 1).
    # EP when the expert count divides the data axes (arctic: the scatter
    # becomes the canonical all-to-all token exchange); otherwise shard the
    # capacity dim (grok).
    from repro.dist.sharding import ambient_dp_size
    from repro.nn import flags as _nn_flags
    dp_size = ambient_dp_size()
    if _nn_flags.EXPERT_PARALLEL and dp_size > 1 and cfg.n_experts % dp_size == 0:
        exe_axes = ("expdp", None, None)
    else:
        exe_axes = (None, "batch", None)
    xe = logical_constraint(xe, *exe_axes)
    ye = tsl.expert_ffn(xe, p["w_gate"], p["w_up"], p["w_down"])
    ye = logical_constraint(ye, *exe_axes)
    y = tsl.moe_combine(ye, info).reshape(b, s, d)

    # Switch-style load-balance aux loss: E * sum_e f_e * p_e
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    importance = jnp.mean(gates, axis=0)
    onehot = jax.nn.one_hot(idx[:, 0], cfg.n_experts, dtype=jnp.float32)
    load = jnp.mean(onehot, axis=0)
    aux = cfg.n_experts * jnp.sum(importance * load)

    if cfg.moe_dense_residual:
        y = y + mlp_forward(p["dense"], x, cfg).reshape(b, s, d)
    return y, aux
