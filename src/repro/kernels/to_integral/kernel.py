"""Pallas TPU kernel: movemask workaround (paper Fig 3/6 MSB-extract).

TPU has no movemask instruction (DESIGN.md §2, changed assumption 3), so —
exactly like the paper's non-BMI2 SSE fallback — this is an `is_native: false`
workaround: a lane-weighted integer reduction. Each VMEM tile is
(bm, 32-lane-packed-into-128) bool; the weighted sum runs on the VPU with
int32 lanes. Input is staged as int8 (Pallas interpret-mode friendly) and
widened in-register.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _to_integral_kernel(m_ref, o_ref, *, n: int):
    m = m_ref[...].astype(jnp.uint32)                      # (bm, n_pad)
    w = jnp.left_shift(
        jnp.uint32(1),
        jax.lax.broadcasted_iota(jnp.uint32, m.shape, 1))
    valid = jax.lax.broadcasted_iota(jnp.int32, m.shape, 1) < n
    o_ref[...] = jnp.sum(jnp.where(valid, m * w, 0), axis=-1,
                         keepdims=True).astype(jnp.uint32)


def to_integral_2d(mask8, *, n: int, block_rows: int = 512,
                   interpret: bool = False):
    """mask8: (rows, n_pad) int8 0/1; returns (rows, 1) uint32."""
    rows, n_pad = mask8.shape
    bm = min(block_rows, rows)
    assert rows % bm == 0
    return pl.pallas_call(
        functools.partial(_to_integral_kernel, n=n),
        grid=(rows // bm,),
        in_specs=[pl.BlockSpec((bm, n_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 1), jnp.uint32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name="tsl_to_integral",
    )(mask8)
