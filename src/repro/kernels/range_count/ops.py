"""Public wrapper: 1-D data of any length -> padded (rows, 128) tile view."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import LANES, cdiv, round_up, sublane_multiple
from . import kernel, ref


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def range_count(data, low, high, *, block_rows: int = 512,
                interpret: bool = False):
    data = data.reshape(-1)
    n = data.shape[0]
    sub = sublane_multiple(data.dtype)
    rows = max(sub, cdiv(n, LANES))
    bm = min(block_rows, round_up(rows, sub))
    rows = round_up(rows, bm)
    padded = jnp.pad(data, (0, rows * LANES - n))
    x2 = padded.reshape(rows, LANES)
    return kernel.range_count_2d(x2, low, high, n_valid=n, block_rows=bm,
                                 interpret=interpret)


__all__ = ["range_count", "ref"]
