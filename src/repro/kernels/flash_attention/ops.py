"""Public wrapper: pads sequence dims to block multiples, restores shape.

Differentiable: the forward pass is the Pallas kernel; the backward pass is
a custom VJP through the jnp oracle (correct, memory-heavier than a flash
backward kernel — the dedicated dq/dk/dv kernel is recorded future work in
DESIGN.md). Training through the TPU-target TSL therefore works today.
"""

from __future__ import annotations

from functools import partial

import jax

from ..common import pad_to
from . import kernel, ref


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5))
def _fa(causal, scale, kv_len, block_q, block_k, interpret, q, k, v):
    qp, _ = pad_to(q, 2, block_q)
    kp, _ = pad_to(k, 2, block_k)
    vp, _ = pad_to(v, 2, block_k)
    out = kernel.flash_attention_4d(
        qp, kp, vp, causal=causal, scale=scale, kv_len=kv_len,
        q_offset=kv_len - q.shape[2], block_q=block_q, block_k=block_k,
        interpret=interpret)
    return out[:, :, :q.shape[2]]


def _fa_fwd(causal, scale, kv_len, block_q, block_k, interpret, q, k, v):
    return _fa(causal, scale, kv_len, block_q, block_k, interpret, q, k, v), \
        (q, k, v)


def _fa_bwd(causal, scale, kv_len, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention(q_, k_, v_, causal=causal,
                                         scale=scale, kv_len=kv_len),
        q, k, v)
    return vjp(g)


_fa.defvjp(_fa_fwd, _fa_bwd)


@partial(jax.jit, static_argnames=("causal", "scale", "kv_len", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    kv_len: int | None = None, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """Flash attention with GQA. q (B,H,Sq,D), k/v (B,KH,Sk,D) -> (B,H,Sq,D).

    Padded q rows are garbage and sliced off; padded k columns are masked by
    kv_len inside the kernel; causal alignment uses the logical sq."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(128, sk))
    kv_len = kv_len if kv_len is not None else sk
    return _fa(causal, scale, kv_len, bq, bk, interpret, q, k, v)


__all__ = ["flash_attention", "ref"]
