"""TSL-Check orchestration: ``run_analysis`` + the ``AnalyzeGPO`` pipeline
operator.

``run_analysis(corpus)`` runs every analyzer family over a validated corpus
(plus the repo's Pallas kernel modules) and applies the per-document
``lint: {suppress: [TSLxxx, ...]}`` suppressions declared in the UPD.

``AnalyzeGPO`` packages the same pass as a corpus-phase GPO so users can
extend the pipeline (paper §3.2 "new GPOs can be added with ease")::

    pipe = CorpusPipeline()
    pipe.insert_after("validate", AnalyzeGPO(fail_on="error"))
    corpus = pipe.build()
"""

from __future__ import annotations

import re
from pathlib import Path

from .cost_check import check_cost_channel
from .coverage import check_coverage
from .findings import AnalysisReport, Finding
from .render import render_bodies
from .safety import check_safety
from .tiling import check_page_geometry, lint_kernel_file, lint_rendered_bodies

_DEF_LOC = re.compile(r"def\[(\d+)\]")


def default_kernel_root() -> Path:
    import repro.kernels

    return Path(repro.kernels.__file__).resolve().parent


def _kernel_geometry(corpus) -> tuple[int, int]:
    """(sublanes, lanes) to lint repo kernels against: the tightest geometry
    among TPU-ish targets, falling back to the schema defaults."""
    geoms = [(t.sublanes, t.lanes) for t in corpus.targets.values()
             if "tpu" in t.flags]
    return max(geoms) if geoms else (8, 128)


def _suppressor(corpus):
    """Build ``suppressed_for(finding) -> bool`` from UPD ``lint:`` blocks."""
    prim_sup: dict[str, set[str]] = {}
    def_sup: dict[tuple[str, int], set[str]] = {}
    for name, prim in corpus.primitives.items():
        codes = set((prim.lint or {}).get("suppress", ()))
        if codes:
            prim_sup[name] = codes
        for i, d in enumerate(prim.definitions):
            dcodes = set((d.lint or {}).get("suppress", ()))
            if dcodes:
                def_sup[(name, i)] = dcodes

    def suppressed(f: Finding) -> bool:
        if not f.subject.startswith("primitive:"):
            return False
        pname = f.subject.split(":", 1)[1]
        if f.code in prim_sup.get(pname, ()):
            return True
        m = _DEF_LOC.match(f.location)
        if m and f.code in def_sup.get((pname, int(m.group(1))), ()):
            return True
        return False

    return suppressed


def run_analysis(corpus, *, kernel_roots: tuple[Path, ...] | None = None,
                 include_corpus_warnings: bool = True) -> AnalysisReport:
    """Run every TSL-Check analyzer family over a validated corpus."""
    rep = AnalysisReport()
    if include_corpus_warnings:
        for w in corpus.warnings:
            rep.add("TSL002", w, subject="corpus")

    rep.extend(check_cost_channel(corpus))
    rep.extend(check_coverage(corpus))

    bodies = render_bodies(corpus)
    for rb in bodies:
        if rb.error:
            rep.add("TSL040", rb.error, subject=f"primitive:{rb.primitive}",
                    location=f"def[{rb.def_index}] {rb.target}")
    ok = [rb for rb in bodies if not rb.error]
    rep.extend(check_safety(ok))
    rep.extend(lint_rendered_bodies(ok))
    rep.extend(check_page_geometry(corpus))

    if kernel_roots is None:
        kernel_roots = (default_kernel_root(),)
    sublanes, lanes = _kernel_geometry(corpus)
    for root in kernel_roots:
        root = Path(root)
        if not root.exists():
            continue
        for path in sorted(root.rglob("kernel.py")):
            rep.extend(lint_kernel_file(path, sublanes=sublanes, lanes=lanes,
                                        root=root.parent))

    rep.apply_suppressions(_suppressor(corpus))
    return rep


class AnalyzeGPO:
    """Corpus-phase GPO: semantic analysis after validation.

    Findings at/above ``fail_on`` become pipeline errors (aborting a strict
    build); everything else lands as warnings prefixed with its TSL code.
    The full report is kept on ``self.report`` for programmatic access.
    """

    name = "analyze"

    def __init__(self, fail_on: str = "error",
                 kernel_roots: tuple[Path, ...] | None = None):
        self.fail_on = fail_on
        self.kernel_roots = kernel_roots
        self.report: AnalysisReport | None = None

    def run(self, ctx):
        corpus = ctx.freeze()
        rep = run_analysis(corpus, kernel_roots=self.kernel_roots,
                           include_corpus_warnings=False)
        self.report = rep
        gate = {"never": (), "error": ("error",),
                "warn": ("error", "warn"),
                "info": ("error", "warn", "info")}[self.fail_on]
        for f in rep.active_findings():
            if f.severity in gate:
                ctx.fail(f.render())
            else:
                ctx.warn(f.render())
        return ctx
