"""Shared building blocks: init helpers + norm dispatch over TSL primitives."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.tsl_api import ops as tsl


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (production LM convention)."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def norm_apply(cfg, w, x, b=None):
    """cfg.norm dispatch: rmsnorm (w) or layernorm (w, b) via TSL."""
    if cfg.norm == "rmsnorm":
        return tsl.rmsnorm(x, w, eps=cfg.norm_eps)
    return tsl.layernorm(x, w, b, eps=cfg.norm_eps)


def init_norm(cfg, dtype):
    w = jnp.ones((cfg.d_model,), dtype)
    if cfg.norm == "rmsnorm":
        return {"w": w}
    return {"w": w, "b": jnp.zeros((cfg.d_model,), dtype)}


def apply_norm_params(cfg, p, x):
    return norm_apply(cfg, p["w"], x, p.get("b"))


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
