"""Pure-jnp oracle for hadd (paper Fig 9)."""

from __future__ import annotations

import jax.numpy as jnp


def hadd(value):
    """Sum over the last axis (f32 accumulation for low precision)."""
    if value.dtype in (jnp.bfloat16, jnp.float16):
        return jnp.sum(value, axis=-1, dtype=jnp.float32).astype(value.dtype)
    return jnp.sum(value, axis=-1)
