"""whisper-tiny [audio]: encoder-decoder, conv frontend STUB.

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865
[arXiv:2212.04356; unverified]

Per the task brief the conv frontend is a stub: input_specs() provides
precomputed frame embeddings (B, S, d_model) for the encoder. The decoder is
causal with cross-attention; decode cells run (self-KV + cross-KV caches).
Whisper uses LayerNorm + GELU (not rmsnorm/swiglu) and learned positions —
modeled via norm="layernorm", act="gelu".
"""

from .arch import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                 # decoder layers
    n_enc_layers=4,             # encoder layers
    enc_dec=True,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    head_dim=64,
    act="gelu",
    norm="layernorm",
    norm_eps=1e-5,
    source="arXiv:2212.04356; unverified",
)
