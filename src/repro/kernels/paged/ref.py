"""Paged cache gather/scatter: the reference bodies behind the
``cache_page_read`` / ``cache_page_write`` UPD primitives.

The pool is a FLAT token-row store ``(capacity_rows, *row_shape)``: one row
per cache token, trailing dims free (a KV row, an (L, KH, hd) stack, an int8
row + its scale row — the primitives are layout-agnostic). A page is
``page_size`` CONSECUTIVE rows, and the page table passed to the primitives
holds each page's STARTING ROW offset, so the same pool array serves any
page-size candidate — the vector-length-agnostic discipline (ARM SVE)
applied to cache geometry: page size is a property of the *definition*, not
of the call site.

Two schedules, mirroring the flash-attention block_k candidates:

* ``page_read``/``page_write`` with small pages — one flat index gather /
  scatter (``jnp.take`` / ``.at[].set``): many small slices, fine-grained
  residency, more index traffic.
* the ``*_blocked`` variants — one ``dynamic_slice`` per page: contiguous
  page-sized block copies, the Mosaic/Triton-friendly schedule for large
  pages (a 256-row page of 128-wide rows is a whole (sublane, lane)-aligned
  tile stream).

Bench selection (``python -m repro.core bench``) times the candidates per
hardware key; the winning definition's page size is what the serving layer
builds its pools with (``repro.serve.paging.selected_page_size`` probes it).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def page_read(pool, table, *, page: int):
    """Gather ``page`` consecutive rows per table entry.

    pool: (cap_rows, *row); table: (N,) int32 page start-row offsets.
    Returns (N * page, *row), pages concatenated in table order."""
    rows = (table[:, None] + jnp.arange(page, dtype=table.dtype)).reshape(-1)
    return jnp.take(pool, rows, axis=0)


def page_read_blocked(pool, table, *, page: int):
    """Same semantics as :func:`page_read`, one contiguous dynamic_slice per
    page — the large-page schedule."""

    def one(start):
        return jax.lax.dynamic_slice_in_dim(pool, start, page, axis=0)

    out = jax.vmap(one)(table)                      # (N, page, *row)
    return out.reshape((-1,) + pool.shape[1:])


def page_write(pool, rows, table, *, page: int):
    """Scatter ``page`` consecutive rows per table entry into the pool.

    rows: (N * page, *row) content in table order; returns the updated pool."""
    idx = (table[:, None] + jnp.arange(page, dtype=table.dtype)).reshape(-1)
    return pool.at[idx].set(rows.astype(pool.dtype))


def page_write_blocked(pool, rows, table, *, page: int):
    """Same semantics as :func:`page_write`, one contiguous
    dynamic_update_slice per page — the large-page schedule."""
    blocks = rows.astype(pool.dtype).reshape((-1, page) + pool.shape[1:])

    def one(p, sb):
        start, blk = sb
        return jax.lax.dynamic_update_slice_in_dim(p, blk, start, axis=0), 0

    pool, _ = jax.lax.scan(one, pool, (table, blocks))
    return pool
