"""Extensibility walk-through (paper §5.3, the FPGA study): integrate a brand
new execution target with PURE DATA — no generator-code changes.

    PYTHONPATH=src python examples/add_new_target.py
"""

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import load_library

TARGET_YAML = """\
---
name: "trn_demo"
vendor: "demo"
description: "Demo accelerator target added at runtime (paper §5.3 analogue)."
lscpu_flags: ["xla", "trn", "pe_array"]
ctypes: ["float32", "bfloat16"]
default_ctype: "float32"
lanes: 128
sublanes: 32
mxu: [128, 128]
vmem_bytes: 25165824
hbm_bytes: 34359738368
peak_flops_bf16: 9.5e+13
hbm_bw: 4.0e+11
ici_bw: 2.0e+10
ici_links: 4
interpret: false
runs_on_host: true
...
"""

# hadd for the new target: the paper's Fig 11 adder tree, written once in the
# UPD — the generator renders, tests and packages it.
PRIMS_YAML = """\
---
primitive_name: "hadd_demo"
group: "demo"
brief: "Adder-tree horizontal add for the demo target (paper Fig 11)."
parameters:
  - {name: "value", ctype: "register"}
returns: {ctype: "register"}
definitions:
  - target_extension: "trn_demo"
    ctype: ["float32", "bfloat16"]
    lscpu_flags: ["xla", "trn", "pe_array"]
    implementation: |
      n = value.shape[-1]
      p = 1 << max(1, (n - 1)).bit_length()
      if p != n:
          value = jnp.pad(value, [(0, 0)] * (value.ndim - 1) + [(0, p - n)])
      while value.shape[-1] > 1:
          half = value.shape[-1] // 2
          value = value[..., :half] + value[..., half:]
      return value[..., 0]
testing:
  - name: "matches_numpy"
    requires: []
    implementation: |
      v = ctx.array((4, 40), ctype, -2, 2)
      ctx.allclose(ops.hadd_demo(v), np.asarray(v, np.float64).sum(-1), ctype, scale=64.0)
...
"""


def main():
    with tempfile.TemporaryDirectory() as tmp:
        root = Path(tmp)
        (root / "targets").mkdir()
        (root / "primitives").mkdir()
        (root / "targets" / "trn_demo.yaml").write_text(TARGET_YAML)
        (root / "primitives" / "demo.yaml").write_text(PRIMS_YAML)
        upd_loc = sum(len(f.read_text().splitlines()) for f in root.rglob("*.yaml"))

        lib = load_library("trn_demo", upd_paths=(str(root),))
        gen_loc = sum(len(p.read_text().splitlines())
                      for p in Path(lib.__file__).parent.rglob("*.py"))
        print(f"[example] new target integrated: {lib.TARGET_NAME}")
        print(f"[example] UPD written: {upd_loc} lines; generated: {gen_loc} "
              f"lines; generator-core changes: 0 "
              f"(paper §5.3: 19 core LOC + ~100 UPD -> 3581 generated)")

        v = jnp.asarray(np.arange(20, dtype=np.float32))
        assert float(lib.ops.hadd_demo(v)) == 190.0
        print(f"[example] hadd_demo(arange(20)) = "
              f"{float(lib.ops.hadd_demo(v))} ✓")


if __name__ == "__main__":
    main()
