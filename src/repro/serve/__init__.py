"""Continuous-batching serving subsystem.

- ``scheduler``: request queue, slot-table lifecycle, SLA accounting,
  ``lib.cost()``-driven admission (host-side control plane, no jax);
- ``slots``: slot-level state access — read a slot back out, validate a
  donor against the slot table (the insert/reset surgery itself lives on
  ``Model.insert_slot``/``reset_slot``, uniform over all four families);
- ``engine``: the per-step continuous-batching loop (jit-stable shapes,
  per-slot positions, TTFT / decode-t/s / SLA metrics).

See README.md in this directory for the slot/state-surgery contract.
"""

from .engine import SamplingConfig, ServeEngine
from .scheduler import (BucketPolicy, CostModelAdmission, Request,
                        RequestMetrics, Scheduler, upd_serve_defaults)
from .slots import take_slot, validate_donor

__all__ = [
    "BucketPolicy",
    "CostModelAdmission",
    "Request",
    "RequestMetrics",
    "SamplingConfig",
    "Scheduler",
    "ServeEngine",
    "take_slot",
    "upd_serve_defaults",
    "validate_donor",
]
