"""Minimal, deterministic stand-in for `hypothesis` (property-based testing).

The test suite uses a small slice of hypothesis' API: ``@settings``,
``@given`` and a handful of strategies. When the real package is installed it
is always preferred (this module registers itself in ``sys.modules`` ONLY if
``import hypothesis`` fails), so CI with pinned deps runs real hypothesis
while minimal containers still execute every property test with seeded
pseudo-random sampling instead of erroring at collection.

Semantic differences vs real hypothesis: no shrinking, no example database,
no health checks — just ``max_examples`` draws from a per-test deterministic
RNG. That keeps the properties exercised and the suite reproducible.
"""

from __future__ import annotations

import inspect
import random
import string
import sys
import types
import zlib

__version__ = "0.0-repro-stub"

_DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self.draw(rng)))

    def filter(self, pred, _tries: int = 100):
        def draw(rng):
            for _ in range(_tries):
                v = self.draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied (stub)")

        return SearchStrategy(draw)


# -- strategies ---------------------------------------------------------------

def integers(min_value=-(2 ** 31), max_value=2 ** 31 - 1):
    return SearchStrategy(lambda rng: rng.randint(int(min_value), int(max_value)))


def floats(min_value=None, max_value=None, *, allow_nan=None, allow_infinity=None,
           width=64, allow_subnormal=None):
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)

    def draw(rng):
        # mix uniform draws with boundary values, like hypothesis favors edges
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        if r < 0.15 and lo <= 0.0 <= hi:
            return 0.0
        return rng.uniform(lo, hi)

    return SearchStrategy(draw)


def booleans():
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def text(alphabet=string.ascii_letters, *, min_size=0, max_size=10):
    chars = list(alphabet)

    def draw(rng):
        n = rng.randint(min_size, max_size)
        return "".join(rng.choice(chars) for _ in range(n))

    return SearchStrategy(draw)


def sampled_from(elements):
    elements = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elements))


def lists(elements, *, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]

    return SearchStrategy(draw)


def frozensets(elements, *, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return frozenset(elements.draw(rng) for _ in range(n))

    return SearchStrategy(draw)


def tuples(*strategies):
    return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def dictionaries(keys, values, *, min_size=0, max_size=8):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return {keys.draw(rng): values.draw(rng) for _ in range(n)}

    return SearchStrategy(draw)


def one_of(*strategies):
    if len(strategies) == 1 and isinstance(strategies[0], (list, tuple)):
        strategies = tuple(strategies[0])
    return SearchStrategy(lambda rng: rng.choice(strategies).draw(rng))


def just(value):
    return SearchStrategy(lambda rng: value)


def none():
    return just(None)


# -- decorators ---------------------------------------------------------------

def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Order-insensitive with @given: records max_examples on the function."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        # hypothesis maps positional strategies onto the RIGHTMOST parameters
        pos_names = names[len(names) - len(arg_strategies):] if arg_strategies else []
        strategy_map = dict(zip(pos_names, arg_strategies))
        strategy_map.update(kw_strategies)
        fixture_names = [n for n in names if n not in strategy_map]

        def wrapper(**fixture_kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES))
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = random.Random(seed)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strategy_map.items()}
                fn(**fixture_kwargs, **drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        if hasattr(fn, "_stub_max_examples"):
            wrapper._stub_max_examples = fn._stub_max_examples
        # pytest reads the signature for fixture injection: expose ONLY the
        # non-strategy parameters
        wrapper.__signature__ = inspect.Signature(
            [sig.parameters[n] for n in fixture_names])
        return wrapper

    return deco


def _register() -> bool:
    """Install the stub as `hypothesis` if the real package is missing."""
    try:
        import hypothesis  # noqa: F401

        return False
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.__version__ = __version__
    mod.given = given
    mod.settings = settings
    mod.strategies = types.ModuleType("hypothesis.strategies")
    for name in ("SearchStrategy", "integers", "floats", "booleans", "text",
                 "sampled_from", "lists", "frozensets", "tuples",
                 "dictionaries", "one_of", "just", "none"):
        setattr(mod.strategies, name, globals()[name])
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies
    return True
