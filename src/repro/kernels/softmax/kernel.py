"""Pallas TPU kernel: row-block softmax, single VMEM pass.

Same tiling family as rmsnorm: (block_rows, d) tiles, f32 max/exp/sum on the
VPU, one HBM read + one write per element (the fused alternative to XLA's
max-read / sub-exp-read / sum-read / div-read chain).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu



def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    p = jnp.exp(x - m)
    o_ref[...] = (p / jnp.sum(p, axis=-1, keepdims=True)).astype(o_ref.dtype)


def softmax_2d(x, *, block_rows: int = 256, interpret: bool = False):
    rows, d = x.shape
    bm = min(block_rows, rows)
    assert rows % bm == 0
    return pl.pallas_call(
        _softmax_kernel,
        grid=(rows // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name="tsl_softmax",
    )(x)
