"""Optimizer, schedule, compression, and data-pipeline tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.train.optimizer import OptConfig, apply_updates, init_opt_state, lr_at


def _quad_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                         jnp.float32)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    return loss, params


@pytest.mark.parametrize("moment_dtype", ["float32", "int8"])
def test_adamw_converges_on_quadratic(moment_dtype):
    loss, params = _quad_problem()
    cfg = OptConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                    total_steps=200, moment_dtype=moment_dtype)
    opt = init_opt_state(cfg, params)
    l0 = float(loss(params))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = apply_updates(cfg, params, g, opt)
    assert float(loss(params)) < l0 * 0.01


def test_int8_moments_track_f32_trajectory():
    loss, params = _quad_problem()
    trajs = {}
    for md in ("float32", "int8"):
        cfg = OptConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                        total_steps=100, moment_dtype=md)
        p = jax.tree.map(jnp.copy, params)
        opt = init_opt_state(cfg, p)
        for _ in range(40):
            g = jax.grad(loss)(p)
            p, opt, _ = apply_updates(cfg, p, g, opt)
        trajs[md] = float(loss(p))
    assert abs(trajs["int8"] - trajs["float32"]) < 0.1 * (trajs["float32"] + 1e-3) + 5e-3


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_at(cfg, 0)) < 0.2
    assert abs(float(lr_at(cfg, 10)) - 1.0) < 0.05
    assert float(lr_at(cfg, 99)) < 0.2
    assert float(lr_at(cfg, 99)) >= 0.1 * 0.9


def test_grad_clip_bounds_update():
    cfg = OptConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0, warmup_steps=1,
                    total_steps=10)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    opt = init_opt_state(cfg, params)
    huge = {"w": jnp.full((4,), 1e6, jnp.float32)}
    _, _, metrics = apply_updates(cfg, params, huge, opt)
    assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4,
                max_size=64))
def test_compression_relative_error_bounded(xs):
    """int8 absmax quantization: error per row bounded by scale/2 ~= amax/254."""
    from repro.dist.compression import compress_decompress

    g = {"w": jnp.asarray(np.array(xs, np.float32)[None, :])}
    out, err = compress_decompress(g)
    amax = max(abs(x) for x in xs)
    bound = (amax / 127.0) * 0.51 + 1e-6
    diff = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max()
    assert diff <= bound


def test_error_feedback_residual_identity():
    """g_quantized + residual == g + residual_in (lossless bookkeeping)."""
    from repro.dist.compression import ErrorFeedback

    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(16,)),
                          jnp.float32)}
    res = ErrorFeedback.init(g)
    out, new_res = ErrorFeedback.apply(g, res)
    np.testing.assert_allclose(np.asarray(out["w"]) + np.asarray(new_res["w"]),
                               np.asarray(g["w"]), rtol=1e-6, atol=1e-6)


# -- data pipeline ------------------------------------------------------------

def test_synthetic_deterministic_and_restart_safe():
    from repro.data.pipeline import SyntheticTokens

    s1 = SyntheticTokens(1000, 4, 16, seed=7)
    s2 = SyntheticTokens(1000, 4, 16, seed=7)
    b1, b2 = s1.batch_at(5), s2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch_at(6)["tokens"], b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_shards_differ():
    from repro.data.pipeline import SyntheticTokens

    a = SyntheticTokens(1000, 8, 16, seed=7, shard=0, n_shards=2).batch_at(0)
    b = SyntheticTokens(1000, 8, 16, seed=7, shard=1, n_shards=2).batch_at(0)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_prefetcher_orders_batches():
    from repro.data.pipeline import DataState, Prefetcher, SyntheticTokens

    src = SyntheticTokens(1000, 2, 8, seed=3)
    state = DataState(step=4)
    pf = Prefetcher(src, state, depth=2)
    got = pf.get()
    np.testing.assert_array_equal(got["tokens"], src.batch_at(4)["tokens"])
    got2 = pf.get()
    np.testing.assert_array_equal(got2["tokens"], src.batch_at(5)["tokens"])
    assert state.step == 6
    pf.stop()


def test_memmap_dataset(tmp_path):
    from repro.data.pipeline import MemmapTokens

    data = np.arange(10_000, dtype=np.uint16) % 500
    f = tmp_path / "tokens.bin"
    data.tofile(f)
    ds = MemmapTokens(f, batch=4, seq=32, seed=0)
    b = ds.batch_at(0)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert (b["tokens"] < 500).all()
