"""Content-addressed artifact store for generated libraries AND
bench-selection winners (paper §4.2: benchmarking alongside adaptive variant
selection "should be integrated as an ongoing process").

Everything the generator emits is addressed by one :class:`CacheKey`:

    (UPD fingerprint, target, probed hardware flags, generator version,
     variant digest of the generation knobs)

so all artifact families share ONE invalidation rule — editing any UPD
document/template/generator source changes the fingerprint, plugging the
library into a different machine changes the probed hardware flags, and a
:data:`GENERATOR_VERSION` bump retires every artifact of the previous engine.
Bench winners deliberately omit the variant digest: a measured winner is a
property of (corpus, target, hardware), not of which package flavour asked
for it.

Layout under the cache root (default ``build/tsl/``)::

    pkg/<package>_<target>_<digest>/   generated library packages
    bench/<target>_<digest>.json       bench-selection winners
    index.json                         digest -> key components (introspection)

SHARED store-root mode (``shared=True``, or ``TSL_STORE_ROOT`` pointing many
processes at one directory) keeps the same content addresses but hardens
every write for concurrency, so a fleet generates and bench-warms each
kernel exactly once:

* packages land under a per-hardware-key namespace
  (``pkg/<hw-namespace>/...``) so heterogeneous machines share one root
  without scanning each other's artifacts;
* ``commit`` stages the package in a private temp dir and publishes it with
  one atomic ``os.rename`` — readers only ever see complete packages, and
  when two writers race the first rename wins while the loser adopts it;
* ``acquire_writer`` is an ``O_CREAT | O_EXCL`` lockfile (the same
  single-publisher discipline as the serve-layer prefix store): exactly one
  process runs the generation, everyone else ``wait_for``s the publish and
  takes the warm hit;
* bench winners and the index are written via temp-file + ``os.replace``
  (the index is additionally rebuilt from the per-package key stamps on
  read, so lost update races cost introspection nothing).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable

# Bump to retire every previously generated artifact (schema change in the
# generated package layout, selection semantics change, ...).
GENERATOR_VERSION = "2.0.0"


@dataclass(frozen=True)
class CacheKey:
    """The content address of one generation run."""

    fingerprint: str                     # UPD + template + generator-source hash
    target: str                          # SRU name
    hardware_flags: tuple[str, ...]      # probed/overridden flags, sorted
    generator_version: str               # GENERATOR_VERSION at generation time
    variant: str = ""                    # digest of generation knobs ("" = bench)

    def digest(self) -> str:
        h = hashlib.sha256()
        for part in (self.fingerprint, self.target, ",".join(self.hardware_flags),
                     self.generator_version, self.variant):
            h.update(part.encode())
            h.update(b"\0")
        return h.hexdigest()[:16]

    def as_dict(self) -> dict:
        return {
            "fingerprint": self.fingerprint,
            "target": self.target,
            "hardware_flags": list(self.hardware_flags),
            "generator_version": self.generator_version,
            "variant": self.variant,
            "digest": self.digest(),
        }

    def without_variant(self) -> "CacheKey":
        """The bench-winner address shared by all package variants."""
        return CacheKey(self.fingerprint, self.target, self.hardware_flags,
                        self.generator_version, "")

    def hw_namespace(self) -> str:
        """Shared-store namespace: a machine-class address. Everything probed
        hardware decides (flags + generator schema) folds in; the corpus
        fingerprint does NOT — a fleet mid-rollout keeps old and new corpus
        artifacts side by side in one namespace."""
        h = hashlib.sha256()
        for part in (",".join(self.hardware_flags), self.generator_version):
            h.update(part.encode())
            h.update(b"\0")
        return f"hw_{h.hexdigest()[:12]}"


def variant_digest(config) -> str:
    """Digest of the generation knobs that change the package *content*
    beyond (corpus, target, hardware)."""
    h = hashlib.sha256(repr((
        sorted(config.only) if config.only else None,
        config.emit_tests, config.emit_docs, config.emit_build,
        config.use_bench_selection, config.package_name,
    )).encode())
    return h.hexdigest()[:8]


class ArtifactCache:
    """Filesystem-backed store; one instance per cache root.

    ``shared=True`` switches every write to the multi-process protocol
    (atomic publish-by-rename, lockfile writer election, namespace
    sub-directories) — see the module docstring. ``namespace`` is the
    per-hardware-key sub-directory (:meth:`CacheKey.hw_namespace`); it
    defaults to flat layout for single-process roots."""

    def __init__(self, root: Path | str, *, shared: bool = False,
                 namespace: str = ""):
        self.root = Path(root)
        self.shared = shared
        self.namespace = namespace

    # -- layout --------------------------------------------------------------

    @property
    def package_root(self) -> Path:
        """Importable package directory (this path goes on ``sys.path``)."""
        return self.root / "pkg" / self.namespace if self.namespace \
            else self.root / "pkg"

    @property
    def bench_root(self) -> Path:
        return self.root / "bench" / self.namespace if self.namespace \
            else self.root / "bench"

    def package_name(self, base: str, key: CacheKey) -> str:
        return f"{base}_{key.target}_{key.digest()[:10]}"

    def package_dir(self, name: str) -> Path:
        return self.package_root / name

    # -- generated packages ---------------------------------------------------

    def lookup(self, name: str) -> Path | None:
        """Committed package dir for ``name``, or None (partial writes — no
        ``_manifest.json`` stamp yet — count as misses)."""
        d = self.package_dir(name)
        return d if (d / "_manifest.json").exists() else None

    def commit(self, name: str, key: CacheKey, files: Iterable) -> Path:
        """Write a generated file set as package ``name`` and stamp it.

        Shared mode publishes by rename: the whole package is staged in a
        private temp dir next to ``pkg/`` and moved into place with ONE
        atomic ``os.rename`` — a concurrent reader sees either nothing or a
        complete, stamped package, never a partial write. If another writer
        already published (we lost a race), the staging copy is discarded
        and the winner's package adopted."""
        pkg_dir = self.package_dir(name)
        if self.shared:
            self.package_root.mkdir(parents=True, exist_ok=True)
            stage = Path(tempfile.mkdtemp(prefix=f".{name}.stage.",
                                          dir=self.package_root))
            write_dir = stage
        else:
            pkg_dir.mkdir(parents=True, exist_ok=True)
            write_dir = pkg_dir
        for f in files:
            out = write_dir / f.relpath
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(f.content)
        (write_dir / "_cache_key.json").write_text(
            json.dumps(key.as_dict(), indent=1))
        if not (write_dir / "_manifest.json").exists():
            # emit_build=False still needs the commit stamp
            (write_dir / "_manifest.json").write_text("{}")
        if self.shared:
            try:
                os.rename(stage, pkg_dir)
            except OSError:
                # a concurrent writer won the publish; adopt its package
                shutil.rmtree(stage, ignore_errors=True)
                if self.lookup(name) is None:
                    raise
        self._index_put(name, key)
        return pkg_dir

    # -- shared-store writer election -----------------------------------------

    @property
    def _lock_root(self) -> Path:
        return self.root / "locks" / self.namespace if self.namespace \
            else self.root / "locks"

    def _lock_path(self, name: str) -> Path:
        return self._lock_root / f"{name}.lock"

    def acquire_writer(self, name: str, *, stale_s: float = 600.0) -> bool:
        """Try to become THE generator for ``name`` (``O_CREAT | O_EXCL``
        lockfile — the prefix-store publisher discipline across processes).
        Returns False when another live process holds the build; a lock
        older than ``stale_s`` (crashed writer) is broken and retaken."""
        self._lock_root.mkdir(parents=True, exist_ok=True)
        path = self._lock_path(name)
        for _ in range(2):
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                try:
                    if time.time() - path.stat().st_mtime > stale_s:
                        path.unlink(missing_ok=True)
                        continue
                except OSError:
                    continue    # holder released between the open and stat
                return False
            with os.fdopen(fd, "w") as fh:
                fh.write(str(os.getpid()))
            return True
        return False

    def release_writer(self, name: str) -> None:
        self._lock_path(name).unlink(missing_ok=True)

    def wait_for(self, name: str, *, timeout_s: float = 600.0,
                 poll_s: float = 0.05) -> Path | None:
        """Block until the elected writer publishes ``name`` (warm-hit path
        of every non-writer process). None on timeout OR once the lock
        disappears without a publish (writer failed) — callers then retry
        the election themselves."""
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            hit = self.lookup(name)
            if hit is not None:
                return hit
            if not self._lock_path(name).exists():
                return self.lookup(name)
            time.sleep(poll_s)
        return None

    # -- bench winners ---------------------------------------------------------

    def bench_path(self, key: CacheKey) -> Path:
        k = key.without_variant()
        return self.bench_root / f"{k.target}_{k.digest()}.json"

    def bench_load(self, key: CacheKey) -> dict:
        p = self.bench_path(key)
        if not p.exists():
            return {}
        try:
            return json.loads(p.read_text())
        except json.JSONDecodeError:
            return {}

    def bench_store(self, key: CacheKey, data: dict) -> Path:
        p = self.bench_path(key)
        p.parent.mkdir(parents=True, exist_ok=True)
        if self.shared:
            # atomic single-file publish: measured winners from two racing
            # warmers are each internally consistent; last replace wins
            fd, tmp = tempfile.mkstemp(prefix=f".{p.name}.", dir=p.parent)
            with os.fdopen(fd, "w") as fh:
                fh.write(json.dumps(data, indent=1))
            os.replace(tmp, p)
        else:
            p.write_text(json.dumps(data, indent=1))
        return p

    # -- index / maintenance ----------------------------------------------------

    @property
    def _index_path(self) -> Path:
        return self.root / "index.json"

    def _index(self) -> dict:
        idx = {}
        if self._index_path.exists():
            try:
                idx = json.loads(self._index_path.read_text())
            except json.JSONDecodeError:
                idx = {}
        if self.shared and self.package_root.is_dir():
            # authoritative source in shared mode is the per-package key
            # stamp — an index write lost to a concurrent replace costs
            # nothing on read
            for pkg in self.package_root.iterdir():
                stamp = pkg / "_cache_key.json"
                if pkg.name not in idx and stamp.exists():
                    try:
                        idx[pkg.name] = json.loads(stamp.read_text())
                    except json.JSONDecodeError:
                        pass
        return idx

    def _index_put(self, name: str, key: CacheKey) -> None:
        idx = self._index()
        idx[name] = key.as_dict()
        self.root.mkdir(parents=True, exist_ok=True)
        if self.shared:
            fd, tmp = tempfile.mkstemp(prefix=".index.", dir=self.root)
            with os.fdopen(fd, "w") as fh:
                fh.write(json.dumps(idx, indent=1))
            os.replace(tmp, self._index_path)
        else:
            self._index_path.write_text(json.dumps(idx, indent=1))

    def stats(self) -> dict:
        pkgs = sorted(p.name for p in self.package_root.iterdir()
                      if p.is_dir() and not p.name.startswith(".")) \
            if self.package_root.is_dir() else []
        benches = sorted(p.name for p in self.bench_root.glob("*.json")) \
            if self.bench_root.is_dir() else []
        return {
            "root": str(self.root),
            "packages": pkgs,
            "bench_entries": benches,
            "index": self._index(),
        }

    def clear(self) -> int:
        """Drop every cached artifact. Returns number of entries removed."""
        n = 0
        for sub in (self.package_root, self.bench_root):
            if sub.is_dir():
                n += sum(1 for _ in sub.iterdir())
                shutil.rmtree(sub)
        if self._index_path.exists():
            self._index_path.unlink()
        return n

    def gc(self, max_age_days: float, *, now: float | None = None) -> int:
        """Age-based eviction: drop packages and bench entries whose artifacts
        were last written more than ``max_age_days`` ago. Recently re-generated
        (touched) artifacts survive; the index is pruned to match. Returns the
        number of entries removed — ``stats``/``clear`` semantics unchanged."""
        import time

        cutoff = (now if now is not None else time.time()) \
            - max_age_days * 86400.0
        removed = 0
        idx = self._index()
        if self.package_root.is_dir():
            for pkg in list(self.package_root.iterdir()):
                if not pkg.is_dir() or pkg.name.startswith("."):
                    continue
                stamp = pkg / "_cache_key.json"
                mtime = (stamp if stamp.exists() else pkg).stat().st_mtime
                if mtime < cutoff:
                    shutil.rmtree(pkg)
                    idx.pop(pkg.name, None)
                    removed += 1
        if self.bench_root.is_dir():
            for bench in list(self.bench_root.glob("*.json")):
                if bench.stat().st_mtime < cutoff:
                    bench.unlink()
                    removed += 1
        if removed and self._index_path.exists():
            self._index_path.write_text(json.dumps(idx, indent=1))
        return removed
