"""Chunked WKV6 — the Finch recurrence as chunk-local matmuls + a cross-chunk
state scan (same decomposition family as ssd/ops.py, but with per-CHANNEL
data-dependent decay, which is RWKV6's distinguishing feature).

Log-space decay bookkeeping keeps the within-chunk decay ratios bounded;
chunk length 32-64 is the numerically comfortable regime (decay ratios are
products of ≤L per-channel w ∈ (0,1]).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref

# Python float, NOT jnp.float32: a module-level device array would be hoisted
# as a closed-over executable constant, which JAX's dispatch can drop across
# repeated calls (observed "supplied 31 buffers but expected 32")
_NEG = -60.0   # exp(-60) == 0 in f32; decay logs are negative


@partial(jax.jit, static_argnames=("chunk",))
def wkv6_chunked(r, k, v, w, u, *, s0=None, chunk: int = 64):
    """Same contract as ref.wkv6_scan."""
    bsz, t, nh, dk = r.shape
    dv = v.shape[-1]
    L = min(chunk, t)
    pad = (-t) % L
    if pad:
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, zpad)
        k = jnp.pad(k, zpad)
        v = jnp.pad(v, zpad)
        w = jnp.pad(w, zpad, constant_values=1.0)
    tt = t + pad
    nc = tt // L

    rf = r.astype(jnp.float32).reshape(bsz, nc, L, nh, dk)
    kf = k.astype(jnp.float32).reshape(bsz, nc, L, nh, dk)
    vf = v.astype(jnp.float32).reshape(bsz, nc, L, nh, dv)
    wf = w.astype(jnp.float32).reshape(bsz, nc, L, nh, dk)
    uf = u.astype(jnp.float32)

    lw = jnp.log(jnp.maximum(wf, 1e-20))
    cum = jnp.cumsum(lw, axis=2)                     # log prod_{j<=t} w_j  (B,C,L,H,K)

    # A_t = prod_{j<=t-1} w_j  (shifted cumulative product; A_1 = 1)
    a_log = cum - lw                                  # log prod_{j<=t-1}

    # intra-chunk, strictly causal s<t:
    #   y_intra[t] = Σ_{s<t} (r_t ⊙ A_t) · (k_s ⊙ (W_chunk/A_{s+1} ... )) v_s
    #   ratio(t,s) = prod_{j=s+1..t-1} w_j = exp(a_log_t - cum_s)
    seg = a_log[:, :, :, None] - cum[:, :, None, :, :, :]    # (B,C,L,L,H,K)
    strict = jnp.tril(jnp.ones((L, L), bool), k=-1)
    seg = jnp.where(strict[None, None, :, :, None, None], seg, _NEG)
    att = jnp.einsum("bcthk,bctshk,bcshk->bctsh", rf, jnp.exp(seg), kf)
    y_intra = jnp.einsum("bctsh,bcshv->bcthv", att, vf)

    # diagonal s == t with the u bonus
    y_diag = jnp.einsum("bcthk,hk,bcthk,bcthv->bcthv", rf, uf, kf, vf)

    # inter-chunk: y_inter[t] = (r_t ⊙ A_t) @ S_prev
    # chunk state update: S_new = diag(prod chunk w) S_prev + Σ_s (prod_{j>s} w_j) k_s ⊗ v_s
    tail = cum[:, :, -1:] - cum                       # log prod_{j=s+1..L}
    chunk_state = jnp.einsum("bcshk,bcshk,bcshv->bchkv", jnp.exp(tail), kf, vf)
    w_chunk = jnp.exp(cum[:, :, -1])                  # (B,C,H,K)

    if s0 is None:
        s0 = jnp.zeros((bsz, nh, dk, dv), jnp.float32)

    def scan_fn(sprev, inp):
        s_c, w_c = inp
        snew = w_c[..., None] * sprev + s_c
        return snew, sprev

    s_final, s_prevs = jax.lax.scan(
        scan_fn, s0,
        (chunk_state.transpose(1, 0, 2, 3, 4), w_chunk.transpose(1, 0, 2, 3)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)        # (B,C,H,K,V)

    y_inter = jnp.einsum("bcthk,bchkv->bcthv", rf * jnp.exp(a_log), s_prevs)

    y = (y_intra + y_diag + y_inter).reshape(bsz, tt, nh, dv)[:, :t]
    return y.astype(r.dtype), s_final


wkv6_scan = ref.wkv6_scan
wkv6_decode_step = ref.wkv6_decode_step

__all__ = ["wkv6_chunked", "wkv6_scan", "wkv6_decode_step", "ref"]
