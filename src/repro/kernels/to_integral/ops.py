"""Public wrapper for the to_integral kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import pad_to, round_up
from . import kernel, ref


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def to_integral(mask, *, block_rows: int = 512, interpret: bool = False):
    """(..., n<=32) bool -> (...,) uint32 bitmask."""
    n = mask.shape[-1]
    assert n <= 32, "integral mask holds 32 lanes (paper §2.2 width pitfall)"
    lead = mask.shape[:-1]
    rows = 1
    for s in lead:
        rows *= s
    m8 = mask.reshape(rows, n).astype(jnp.int8)
    m8, _ = pad_to(m8, 1, 128)          # lane alignment
    sub = 32                            # int8 sublane multiple
    bm = min(block_rows, round_up(rows, sub))
    m8, _ = pad_to(m8, 0, bm)
    out = kernel.to_integral_2d(m8, n=n, block_rows=bm, interpret=interpret)
    return out[:rows, 0].reshape(lead)


__all__ = ["to_integral", "ref"]
