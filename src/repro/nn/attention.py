"""Attention layer on TSL primitives: GQA + RoPE + optional qk_norm/bias.

Full-sequence path uses tsl.flash_attention (Pallas on TPU targets);
decode path uses tsl.attention_decode + tsl.cache_update (KV cache layout
(B, KH, S_max, hd) — heads-major so the TP shard dim is contiguous).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.tsl_api import ops as tsl

from .common import dense_init, split_keys
from .rope import rope_tables


def init_attention(key, cfg, dtype):
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, kh * hd), dtype),
        "wv": dense_init(ks[2], (d, kh * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kh * hd,), dtype)
        p["bv"] = jnp.zeros((kh * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(p, x, cfg, positions):
    """x: (B,S,D) -> q (B,H,S,hd), k/v (B,KH,S,hd) with RoPE applied."""
    b, s, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = tsl.matmul(x, p["wq"])
    k = tsl.matmul(x, p["wk"])
    v = tsl.matmul(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kh, hd)
    v = v.reshape(b, s, kh, hd)
    if cfg.qk_norm:
        q = tsl.rmsnorm(q, p["q_norm"], eps=cfg.norm_eps)
        k = tsl.rmsnorm(k, p["k_norm"], eps=cfg.norm_eps)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)   # (S, hd/2) or (B,S,hd/2)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    q = tsl.rope_apply(q, cos, sin)
    k = tsl.rope_apply(k, cos, sin)
    # heads-major, heads TP-sharded (megatron-style attention parallelism)
    from repro.dist.sharding import logical_constraint
    q = logical_constraint(q.transpose(0, 2, 1, 3), "batch", "heads", None, None)
    k = logical_constraint(k.transpose(0, 2, 1, 3), "batch", "heads", None, None)
    v = logical_constraint(v.transpose(0, 2, 1, 3), "batch", "heads", None, None)
    return q, k, v


def attention_forward(p, x, cfg, *, causal: bool = True, positions=None):
    """Full-sequence attention. x: (B,S,D) -> (B,S,D); returns (y, (k, v))."""
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = tsl.flash_attention(q, k, v, causal=causal)          # (B,H,S,hd)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    return tsl.matmul(o, p["wo"]), (k, v)


def cross_attention_forward(p, x, k, v, cfg):
    """Decoder cross-attention against precomputed encoder K/V."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q = tsl.matmul(x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    o = tsl.flash_attention(q, k, v, causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return tsl.matmul(o, p["wo"])


def project_kv(p, x, cfg):
    """Encoder-side K/V projection for cross attention. x: (B,S,D)."""
    b, s, _ = x.shape
    kh, hd = cfg.n_kv_heads, cfg.hd
    k = tsl.matmul(x, p["wk"])
    v = tsl.matmul(x, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return (k.reshape(b, s, kh, hd).transpose(0, 2, 1, 3),
            v.reshape(b, s, kh, hd).transpose(0, 2, 1, 3))


def attention_prefill_chunk(p, x, k_cache, v_cache, pos, cfg):
    """Continuation prefill of one chunk into an existing cache.

    x: (B, C, D) chunk activations; caches (B, KH, S_max, hd) filled to
    ``pos`` real rows. Writes the chunk's K/V at rows [pos, pos+C) and
    attends the chunk queries against the whole cache through
    ``tsl.attention_prefill_chunk`` (causal, ends-aligned at pos+C).

    Rows the caller marks as padding (its ``n_real < C``) need no masking
    here: a padded row i >= n_real sits at position pos+i, strictly AFTER
    every real row, so the causal mask already hides its key from every real
    query; its own output row is garbage the caller discards, and its cache
    row lies beyond the real fill (pos+n_real) where the decode-path kv_len
    mask hides it until the next chunk/decode step overwrites it.

    ``pos`` may be traced (jit-stable over cache fill) and may be a (B,)
    vector of PER-SLOT base positions (ragged chunks over the slot table:
    RoPE, the slab scatter, and kv_len all become per-slot). Returns
    (y (B,C,D), k_cache', v_cache')."""
    return _span_attend(p, x, k_cache, v_cache, pos, cfg,
                        tsl.attention_prefill_chunk)


def attention_verify(p, x, k_cache, v_cache, pos, cfg):
    """Speculative-decoding verify span: x (B,SV,D) holds each slot's pending
    token + drafted continuation; ``pos`` is the span's base write position
    (scalar or (B,) per-slot). Writes the span's K/V at rows [pos, pos+SV)
    and scores every row in ONE ragged batched step through
    ``tsl.attention_verify`` (causal, ends-aligned at pos+SV), so row j's
    output is independent of rows > j — the accepted-prefix contract.
    Rollback of rejected rows is free: they lie beyond the committed kv_len,
    where the decode-path mask hides them until overwritten.

    Returns (y (B,SV,D), k_cache', v_cache')."""
    return _span_attend(p, x, k_cache, v_cache, pos, cfg, tsl.attention_verify)


def _span_attend(p, x, k_cache, v_cache, pos, cfg, span_op):
    """Shared prefill-chunk / verify-span body: project, slab-write, attend."""
    b, c, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    pos = jnp.asarray(pos)
    per_slot = pos.ndim == 1
    positions = (pos[:, None] + jnp.arange(c)[None, :] if per_slot
                 else pos + jnp.arange(c))
    # same projection pipeline (bias/qk_norm/RoPE/TP sharding) as the
    # full-sequence path — q/k/v come back heads-major (B,{H|KH},C,hd)
    q, k, v = _project_qkv(p, x, cfg, positions)
    if per_slot:
        # per-slot slab scatter: vmap the TSL update over the batch axis so
        # each slot writes its C rows at its own base (leaf (KH,S,hd): the
        # update (KH,C,hd) lands along axis 1 = S)
        upd = jax.vmap(tsl.cache_update)
        k_cache = upd(k_cache, k, pos)
        v_cache = upd(v_cache, v, pos)
    else:
        # contiguous C-row slab write at the chunk's base position (cache
        # layout (B,KH,S,hd): tsl.cache_update writes along axis 1 -> swap
        # S forward)
        k_cache = jnp.swapaxes(
            tsl.cache_update(jnp.swapaxes(k_cache, 1, 2),
                             k.transpose(0, 2, 1, 3), pos), 1, 2)
        v_cache = jnp.swapaxes(
            tsl.cache_update(jnp.swapaxes(v_cache, 1, 2),
                             v.transpose(0, 2, 1, 3), pos), 1, 2)
    o = span_op(q, k_cache, v_cache, kv_len=pos + c)
    o = o.transpose(0, 2, 1, 3).reshape(b, c, h * hd)
    return tsl.matmul(o, p["wo"]), k_cache, v_cache


def attention_span_paged(p, x, k_pool, v_pool, tables, pos, cfg, span_op, *,
                         k_scale=None, v_scale=None):
    """Fused paged decode/verify span: project a span of C tokens per slot,
    write each row STRAIGHT into its block-table page, and attend directly
    against the page pool — no page->lane gather anywhere.

    x: (B, C, D) span activations (C == 1 is the decode step); pools
    (KH, n_pages, page, hd) — one layer's slice of the serve-layer pool;
    tables (B, P) int32 page ids; ``pos`` scalar or (B,) per-slot base write
    positions. ``span_op`` is ``tsl.attention_decode_paged`` (C == 1) or
    ``tsl.attention_verify_paged``; both mask ends-aligned at kv_len =
    pos + C, so rows beyond a slot's committed fill are dead — rollback
    stays free exactly as in the lane path. ``k_scale``/``v_scale``
    (KH, n_pages, page, 1) switch the pools to the absmax-int8 wire format:
    rows quantize per write and dequantize per touched page inside the
    primitive. Inactive slots must point at a scratch page (valid id):
    their row writes and reads land there harmlessly.

    Returns (y (B,C,D), k_pool', v_pool', k_scale', v_scale')."""
    from repro.dist.compression import quantize_absmax_int8

    b, c, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    page = k_pool.shape[-2]
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    positions = pos[:, None] + jnp.arange(c)[None, :]          # (B, C)
    q, k, v = _project_qkv(p, x, cfg, positions)  # q (B,H,C,hd) k/v (B,KH,C,hd)
    tab = jnp.asarray(tables, jnp.int32)
    pid = jnp.take_along_axis(tab, positions // page, axis=1)  # (B, C)
    off = positions % page
    # pool.at[:, pid, off] broadcasts the (B, C) index pair under the KH
    # slice -> (KH, B, C, hd) update slabs, heads-major like the pool
    kr = jnp.swapaxes(k, 0, 1)
    vr = jnp.swapaxes(v, 0, 1)
    if k_scale is not None:
        qk, sk = quantize_absmax_int8(kr)
        qv, sv = quantize_absmax_int8(vr)
        k_pool = k_pool.at[:, pid, off].set(qk)
        v_pool = v_pool.at[:, pid, off].set(qv)
        k_scale = k_scale.at[:, pid, off].set(sk)
        v_scale = v_scale.at[:, pid, off].set(sv)
    else:
        k_pool = k_pool.at[:, pid, off].set(kr.astype(k_pool.dtype))
        v_pool = v_pool.at[:, pid, off].set(vr.astype(v_pool.dtype))
    o = span_op(q, k_pool, v_pool, tab, kv_len=pos + c,
                k_scale=k_scale, v_scale=v_scale)
    o = o.transpose(0, 2, 1, 3).reshape(b, c, h * hd)
    return tsl.matmul(o, p["wo"]), k_pool, v_pool, k_scale, v_scale


def attention_decode(p, x_t, k_cache, v_cache, pos, cfg, *, rope: bool = True):
    """One-token decode. x_t: (B,1,D); caches (B,KH,S_max,hd); pos: scalar
    write index, or a (B,) vector of PER-SLOT write indices (continuous
    batching: each slot of the live batch sits at its own position — RoPE,
    the cache scatter, and the kv_len mask all become per-slot).

    Returns (y (B,1,D), k_cache', v_cache')."""
    b = x_t.shape[0]
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    pos = jnp.asarray(pos)
    per_slot = pos.ndim == 1
    q = tsl.matmul(x_t, p["wq"])
    k = tsl.matmul(x_t, p["wk"])
    v = tsl.matmul(x_t, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, h, hd)
    k = k.reshape(b, 1, kh, hd)
    v = v.reshape(b, 1, kh, hd)
    if cfg.qk_norm:
        q = tsl.rmsnorm(q, p["q_norm"], eps=cfg.norm_eps)
        k = tsl.rmsnorm(k, p["k_norm"], eps=cfg.norm_eps)
    if rope:
        if per_slot:
            cos, sin = rope_tables(pos[:, None], hd, cfg.rope_theta)  # (B,1,hd/2)
            cos, sin = cos[:, :, None, :], sin[:, :, None, :]
        else:
            cos, sin = rope_tables(pos[None], hd, cfg.rope_theta)
            cos, sin = cos[None, :, None, :], sin[None, :, None, :]
        q = tsl.rope_apply(q, cos, sin)
        k = tsl.rope_apply(k, cos, sin)
    q = q.transpose(0, 2, 1, 3)
    if per_slot:
        # per-slot scatter: vmap the TSL update over the batch axis, so each
        # slot writes its own row (cache leaf (KH,S,hd): axis 1 is still S)
        upd = jax.vmap(tsl.cache_update)
        k_cache = upd(k_cache, k.transpose(0, 2, 1, 3), pos)
        v_cache = upd(v_cache, v.transpose(0, 2, 1, 3), pos)
    else:
        # cache layout (B,KH,S,hd): update along axis 2 -> move axis for
        # tsl.cache_update (axis 1)
        k_cache = jnp.swapaxes(
            tsl.cache_update(jnp.swapaxes(k_cache, 1, 2), k, pos), 1, 2)
        v_cache = jnp.swapaxes(
            tsl.cache_update(jnp.swapaxes(v_cache, 1, 2), v, pos), 1, 2)
    o = tsl.attention_decode(q, k_cache, v_cache, kv_len=pos + 1)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * hd)
    return tsl.matmul(o, p["wo"]), k_cache, v_cache
