"""Paper §4.2 (future work there, implemented here): benchmark-driven
adaptive variant selection. Generates the cpu_xla library twice — once with
the flag heuristic, once with the BenchSelectGPO — and reports which
primitives changed implementation and the measured per-variant timings.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.core import load_library

from .common import emit


def run() -> list[str]:
    lib_flags = load_library("cpu_xla", use_bench_selection=False)
    lib_bench = load_library("cpu_xla", use_bench_selection=True)
    man_f = json.loads((Path(lib_flags.__file__).parent / "_manifest.json").read_text())
    man_b = json.loads((Path(lib_bench.__file__).parent / "_manifest.json").read_text())
    out = []
    changed = 0
    for prim, per_ct in man_b["primitives"].items():
        for ct, sel in per_ct.items():
            if sel["selected_by"] == "bench":
                base = man_f["primitives"][prim][ct]
                delta = "same" if base["required_flags"] == sel["required_flags"] \
                    else "CHANGED"
                if delta == "CHANGED":
                    changed += 1
                emit(f"adaptive_{prim}_{ct}", 0,
                     f"by=bench flags={sel['required_flags']} vs_heuristic={delta}")
                out.append(f"{prim}/{ct}: bench-selected ({delta})")
    # timings live in the unified artifact cache (bench/ family)
    cache_dir = Path(lib_bench.__file__).parents[2] / "bench"
    for f in sorted(cache_dir.glob("cpu_xla_*.json")):
        cache = json.loads(f.read_text())
        for key, rec in cache.items():
            times = ", ".join(f"{t:.0f}us" for t in rec["times_us"])
            emit(f"adaptive_timings_{key.replace('/', '_')}", 0, times)
    out.append(f"{changed} selections changed vs flag heuristic")
    return out


if __name__ == "__main__":
    run()
