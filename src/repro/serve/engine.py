"""Per-step continuous-batching serving engine.

One fixed-shape batched decode state (the slot table) runs one jitted decode
step per iteration; ANY slot that frees is refilled from the request queue
BEFORE the next step — a finished sequence never idles its slot while
neighbours drain (the wave-loop failure mode this engine replaces). All
device-side shapes are static — (B, 1) tokens, (B,) per-slot positions, the
state pytree — so the decode step compiles exactly once per engine, and
admission is state surgery (``Model.insert_slot``), not reshaping.

Prefill runs per request at its natural prompt length (one compile per
distinct length — callers who care bucket their prompt lengths), then the
prefilled single-request state is grafted into the freed slot.

Metrics per request: TTFT, decode tokens/s, end-to-end latency, SLA hit;
per engine run: real-token throughput (padded/idle slots never counted),
steady-state padded-slot steps (0 == true continuous batching), slot-reuse
counts, the admission log, and every refusal with its cost-model reason.
"""

from __future__ import annotations

import time
from dataclasses import asdict, dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.model import build_model

from .scheduler import CostModelAdmission, Request, Scheduler
from .slots import validate_donor


@dataclass(frozen=True)
class SamplingConfig:
    """temperature <= 0 -> greedy argmax; top_k 0 -> no truncation."""

    temperature: float = 0.0
    top_k: int = 0


class ServeEngine:
    def __init__(self, cfg, *, batch: int, max_len: int,
                 sampling: SamplingConfig | None = None, seed: int = 0,
                 enc_len: int | None = None, admission: bool = True):
        if cfg.family == "audio" and enc_len is None:
            raise ValueError("audio family: pass enc_len (the fixed encoder "
                             "length every request's frames are sized to)")
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.enc_len = enc_len
        self.sampling = sampling or SamplingConfig()
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        # the donor cache is filled to prompt_len + decode_prefix (vlm vision
        # rows), and decode must write AFTER it
        self._prefix = cfg.decode_prefix
        self.cost_model = CostModelAdmission(cfg, batch, max_len,
                                             enc_len=enc_len) \
            if admission else None
        self._prefill = jax.jit(self.model.prefill, static_argnums=(2,))
        # donate the incoming state: it is dead after every call, and without
        # donation each step/insert/reset copies the full multi-layer cache
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._insert = jax.jit(self.model.insert_slot, donate_argnums=(0,))
        self._reset = jax.jit(self.model.reset_slot, donate_argnums=(0,))
        self._sample = self._build_sampler()
        self._key = jax.random.PRNGKey(seed + 1)

    # -- helpers --------------------------------------------------------------

    def _build_sampler(self):
        temp, top_k = self.sampling.temperature, self.sampling.top_k
        vocab = self.cfg.vocab

        def mask_padding(logits):
            # the lm head is padded_vocab wide: never emit a padding id
            keep = jnp.arange(logits.shape[-1]) < vocab
            return jnp.where(keep, logits, jnp.full_like(logits, -1e30))

        if temp <= 0.0:
            def sample(logits, key):
                return jnp.argmax(mask_padding(logits), axis=-1)
        else:
            def sample(logits, key):
                scaled = mask_padding(logits).astype(jnp.float32) / temp
                if top_k:
                    kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
                    scaled = jnp.where(scaled < kth, jnp.float32(-1e30), scaled)
                return jax.random.categorical(key, scaled, axis=-1)

        return jax.jit(sample)

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _make_batch(self, prompts: np.ndarray, embeds=None) -> dict:
        cfg = self.cfg
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = (
                jnp.asarray(embeds, cfg.dtype)[None] if embeds is not None
                else jnp.zeros((len(prompts), cfg.vision_prefix, cfg.d_model),
                               cfg.dtype))
        if cfg.family == "audio":
            batch["audio_embeds"] = (
                jnp.asarray(embeds, cfg.dtype)[None] if embeds is not None
                else jnp.zeros((len(prompts), self.enc_len, cfg.d_model),
                               cfg.dtype))
        return batch

    def _init_state(self):
        return self.model.init_decode_state(self.batch, self.max_len,
                                            enc_len=self.enc_len)

    # -- the serving loop -----------------------------------------------------

    def run(self, requests: list[Request]) -> dict:
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError("duplicate request rids (outputs and metrics "
                             "are keyed by rid)")
        bad = [r.rid for r in requests if r.gen_len < 1]
        if bad:
            raise ValueError(f"gen_len must be >= 1 (requests {bad}); the "
                             "first token always comes from prefill")
        sched = Scheduler(self.batch, admission=self.cost_model)
        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0  # noqa: E731
        for r in requests:
            sched.submit(r, now())

        state = self._init_state()
        tokens = jnp.zeros((self.batch, 1), jnp.int32)
        pos_host = np.zeros(self.batch, np.int64)
        outputs: dict[str, list[int]] = {}
        step = 0
        padded_steady = 0
        generated = 0

        while sched.has_work():
            # admission phase: refill EVERY free slot before the next step —
            # re-reading free_slots() each pass, since a gen_len==1 request
            # completes AT admission and frees its slot for the next in line
            while True:
                free = sched.free_slots()
                if not free:
                    break
                req = sched.next_admissible(now())
                if req is None:
                    break
                slot = free[0]
                prompt = np.asarray(req.tokens, np.int64)[None, :]
                logits1, donor = self._prefill(
                    self.params, self._make_batch(prompt, req.embeds),
                    self.max_len)
                validate_donor(state, donor,
                               self.model.state_batch_axes(state))
                state = self._insert(state, donor, slot)
                first = int(np.asarray(
                    self._sample(logits1, self._next_key()))[0])
                sched.place(req, slot, step)
                sched.first_token(slot, now())
                generated += 1
                outputs[req.rid] = [first]
                tokens = tokens.at[slot, 0].set(first)
                pos_host[slot] = req.prompt_len + self._prefix
                if sched.slot_done(slot):        # gen_len == 1 edge case
                    sched.finish(slot, now())
                    state = self._reset(state, slot)

            active = sched.active_slots()
            if not active:
                # nothing decoding (e.g. every admitted request finished at
                # admission with gen_len == 1) — but the queue may still hold
                # work, so loop back to admission rather than exiting
                continue
            if sched.queue:
                # queue still has work: every free slot this step is waste.
                # With per-step admission this is 0 by construction — the
                # counter is a tripwire so any future scheduling policy that
                # delays admission (waves, arrival times, deferred refusals)
                # surfaces its cost here instead of silently regressing
                padded_steady += self.batch - len(active)

            if int(pos_host[active].max()) >= self.max_len:
                # reachable only with admission=False (admission's
                # over_budget check forbids it): fail loudly rather than
                # silently clobbering the last cache row
                raise RuntimeError(
                    f"active slot position {int(pos_host[active].max())} "
                    f"overran max_len={self.max_len}")
            pos_vec = jnp.asarray(pos_host, jnp.int32)
            logits, state = self._decode(self.params, state, tokens, pos_vec)
            toks = np.asarray(self._sample(logits, self._next_key()))
            tokens = jnp.asarray(toks[:, None], jnp.int32)
            for slot in active:
                rid = sched.slots[slot].request.rid
                sched.step_done(slot)
                pos_host[slot] += 1
                outputs[rid].append(int(toks[slot]))
                generated += 1
                if sched.slot_done(slot):
                    sched.finish(slot, now())
                    state = self._reset(state, slot)
            step += 1

        wall = max(now(), 1e-9)
        finished = sched.finished
        ttfts = [m.ttft_s for m in finished]
        report = {
            "arch": self.cfg.name,
            "requests": len(finished),
            "generated_tokens": generated,
            "decode_tokens_per_s": generated / wall,
            "steps": step,
            "wall_s": wall,
            "padded_slot_steps_steady": padded_steady,
            "ttft_s_mean": float(np.mean(ttfts)) if ttfts else 0.0,
            "sla_hit_rate": sched.sla_hit_rate(),
            "slot_reuse": sched.slot_reuse(),
            "admission_log": sched.admission_log,
            "per_request": [asdict(m) for m in finished],
            "refused": [{"rid": r.rid, "reason": r.reason}
                        for r in sched.refused],
            "outputs": outputs,
        }
        if self.cost_model is not None:
            report["cost_model"] = {
                "decode_bytes_per_step": self.cost_model.decode_bytes_per_step(),
                "step_seconds": self.cost_model.step_seconds(),
            }
        return report
