"""Generator-core unit tests: schema, validation GPO, pipeline mechanics."""

import pytest

from repro.core import CorpusPipeline, GenConfig, GenerationError, core_pipeline
from repro.core.model import CorpusBuild
from repro.core.pipeline import Pipeline, TemplateCheckGPO
from repro.core.schema import Entry, PRIMITIVE_SCHEMA, Schema, TARGET_SCHEMA
from repro.core.validate import ValidateGPO


def test_schema_mandatory_missing():
    s = Schema("t", (Entry("a", "str", mandatory=True),))
    out, errs, warns = s.apply({})
    assert errs and "mandatory" in errs[0]


def test_schema_defaults_enrich():
    s = Schema("t", (Entry("a", "str", mandatory=True),
                     Entry("b", "int", default=7)))
    out, errs, _ = s.apply({"a": "x"})
    assert not errs and out["b"] == 7


def test_schema_type_errors_reported_not_thrown():
    s = Schema("t", (Entry("a", "int", mandatory=True),))
    out, errs, _ = s.apply({"a": "not-an-int"})
    assert errs and "expected int" in errs[0]


def test_schema_extra_fields_pass_through_with_warning():
    """Paper ⑥: arbitrary additional fields are allowed."""
    s = Schema("t", (Entry("a", "str", default=""),))
    out, errs, warns = s.apply({"zzz": 1})
    assert not errs and out["zzz"] == 1
    assert any("extra field" in w for w in warns)


def test_schema_composed_list_paths_in_errors():
    out, errs, _ = PRIMITIVE_SCHEMA.apply({
        "primitive_name": "p",
        "definitions": [{"ctype": ["float32"], "implementation": "pass"}],
    })
    assert any("definitions[0].target_extension" in e for e in errs)


def test_bool_is_not_int():
    s = Schema("t", (Entry("a", "int", mandatory=True),))
    _, errs, _ = s.apply({"a": True})
    assert errs


def test_validate_gpo_rejects_unknown_target_reference():
    ctx = CorpusBuild()
    ctx.raw_targets = [{"name": "cpu_xla", "lscpu_flags": ["xla"],
                        "ctypes": ["float32"]}]
    ctx.raw_primitives = [{
        "primitive_name": "p", "group": "g",
        "definitions": [{"target_extension": "nonexistent",
                         "ctype": ["float32"], "implementation": "pass"}],
    }]
    ValidateGPO().run(ctx)
    assert any("unknown" in e and "nonexistent" in e for e in ctx.errors)


def test_validate_gpo_warns_on_untested_primitive():
    ctx = CorpusBuild()
    ctx.raw_targets = [{"name": "cpu_xla", "lscpu_flags": ["xla"],
                        "ctypes": ["float32"]}]
    ctx.raw_primitives = [{
        "primitive_name": "p", "group": "g",
        "definitions": [{"target_extension": "cpu_xla",
                         "ctype": ["float32"], "implementation": "return 1"}],
    }]
    ValidateGPO().run(ctx)
    assert any("no test cases" in w for w in ctx.warnings)


def test_pipeline_is_exchangeable():
    """Paper ①: GPOs remain exchangeable / pipeline can be altered."""
    config = GenConfig(target="cpu_xla")
    pipe = core_pipeline(config)
    names = pipe.names()
    assert names[:2] == ["select", "generate"]
    # corpus-phase GPOs run once per fingerprint, not per target
    assert CorpusPipeline().names() == ["template-check", "validate"]

    class NoopGPO:
        name = "noop"

        def run(self, ctx):
            ctx.meta["noop_ran"] = True
            return ctx

    pipe.insert_after("select", NoopGPO())
    assert "noop" in pipe.names()
    ctx = pipe.run(config)
    assert ctx.meta["noop_ran"]


def test_pipeline_replace_unknown_raises():
    pipe = Pipeline([TemplateCheckGPO()])
    with pytest.raises(KeyError):
        pipe.replace("nope", TemplateCheckGPO())


def test_pipeline_insert_after_unknown_raises():
    pipe = Pipeline([TemplateCheckGPO()])
    with pytest.raises(KeyError, match="nope"):
        pipe.insert_after("nope", TemplateCheckGPO())


def test_pipeline_replace_swaps_in_place():
    class A:
        name = "a"

        def run(self, ctx):
            return ctx

    class B:
        name = "b"

        def run(self, ctx):
            return ctx

    pipe = Pipeline([A(), TemplateCheckGPO()])
    pipe.replace("a", B())
    assert pipe.names() == ["b", "template-check"]
    with pytest.raises(KeyError):
        pipe.replace("a", B())         # old name is gone after the swap


def test_full_pipeline_fails_on_bad_target():
    with pytest.raises(GenerationError):
        core_pipeline(GenConfig(target="not-a-target")).run(
            GenConfig(target="not-a-target"))


def test_target_schema_accepts_real_files():
    from repro.core import loader

    docs = loader.load_raw_targets()
    assert len(docs) >= 4
    for d in docs:
        d = {k: v for k, v in d.items() if not k.startswith("__")}
        _, errs, _ = TARGET_SCHEMA.apply(d)
        assert not errs, errs
