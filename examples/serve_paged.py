"""Paged slot memory demo: many requests sharing one system prompt, served
inside an HBM budget that a contiguous slot table could spend on only TWO
max-length reservations.

The paged engine charges HBM for pages actually produced, shares the system
prompt's pages copy-on-write through the content-addressed prefix store
(prefilled ONCE, asserted via the chunk count), and parks completed prefills
in pages until a lane frees — so residency is bounded by pages, not lanes:

    PYTHONPATH=src python examples/serve_paged.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.serve import PagedConfig, Request, ServeEngine  # noqa: E402

N_REQUESTS = 10
SYSTEM_LEN = 16          # shared system prompt (page-aligned at page 16)
UNIQUE_LEN = 8
GEN_LEN = 4
MAX_LEN = 96
PAGE = 16


def main():
    cfg = get_config("qwen1.5-0.5b").reduced()

    # budget = what a CONTIGUOUS slot table spends on just 2 worst-case
    # lanes; the paged engine must fit far more residency into the same HBM
    probe = ServeEngine(cfg, batch=2, max_len=MAX_LEN, seed=0,
                        paged=PagedConfig(page_size=PAGE))
    budget = 2 * probe._store.contiguous_bytes_per_slot(MAX_LEN)
    del probe

    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab, SYSTEM_LEN).astype(np.int32)
    requests = []
    for i in range(N_REQUESTS):
        toks = np.concatenate(
            [system, rng.integers(0, cfg.vocab, UNIQUE_LEN).astype(np.int32)])
        requests.append(Request(rid=f"r{i}", tokens=toks, gen_len=GEN_LEN,
                                shared_prefix_len=SYSTEM_LEN))

    jax.clear_caches()
    engine = ServeEngine(
        cfg, batch=2, max_len=MAX_LEN, seed=0,
        paged=PagedConfig(page_size=PAGE, hbm_budget_bytes=budget,
                          max_inflight_prefills=N_REQUESTS))
    report = engine.run(requests)

    pg = report["paged"]
    print(f"[example] {report['requests']} requests on 2 lanes, "
          f"budget {budget / 1e6:.2f} MB "
          f"(= {pg['contiguous_resident_bound']} contiguous slots)")
    print(f"[example] resident peak {pg['resident_requests_peak']} requests, "
          f"{pg['pages_used_peak']}/{pg['n_pages']} pages "
          f"({pg['hbm_bytes_resident_peak'] / 1e6:.2f} MB peak)")
    print(f"[example] prefix store: {pg['prefix_hits']} hits / "
          f"{pg['prefix_misses']} miss, cow copies {pg['cow_copies']}")

    assert report["requests"] == N_REQUESTS, report
    assert all(len(report["outputs"][r.rid]) == GEN_LEN for r in requests)

    # the headline: >= 4x the residency of the contiguous bound, same HBM
    bound = pg["contiguous_resident_bound"]
    assert pg["resident_requests_peak"] >= 4 * bound, pg

    # the shared system prompt was prefilled exactly once
    assert pg["prefix_hits"] == N_REQUESTS - 1, pg
    assert pg["prefix_misses"] == 1, pg
    chunk = engine.policy.chunk
    bucket = report["per_request"][0]["bucket"]
    chunks = sum(e["chunks"] for e in report["step_log"])
    want = bucket // chunk + (N_REQUESTS - 1) * ((bucket - SYSTEM_LEN) // chunk)
    assert chunks == want, (chunks, want)
    print(f"[example] prefill chunks {chunks} == {want} "
          f"(system prompt prefilled once)")


if __name__ == "__main__":
    main()
