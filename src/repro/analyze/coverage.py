"""Coverage-matrix insight analyzer (TSL02x).

Materializes the primitive × target × ctype availability matrix the paper's
"valuable insights for assessing provided functionality" claim implies, and
turns its asymmetries into coded findings:

* a primitive covered by some targets but not others (TSL020) — a library
  generated for an uncovered target silently omits the op;
* definitions with no ``testing:`` entry (TSL021, the coded version of the
  paper-§4.1 warning ValidateGPO already emits);
* definitions gated on feature flags that *no* SRU document declares —
  hwprobe reads flags from the SRU documents, so such a definition can never
  become valid (TSL022);
* dead candidates: definitions that on every (target, ctype) either lose the
  flag heuristic with no ``bench:`` setup to overrule it, or are invalid
  outright (TSL023);
* definition ctypes the target SRU does not offer (TSL024).
"""

from __future__ import annotations

from repro.core import select
from .findings import AnalysisReport


def availability_matrix(corpus) -> dict[str, dict[str, list[str]]]:
    """primitive -> target -> [ctypes with a valid selection]."""
    matrix: dict[str, dict[str, list[str]]] = {}
    for name in sorted(corpus.primitives):
        prim = corpus.primitives[name]
        row: dict[str, list[str]] = {}
        for tname in sorted(corpus.targets):
            tgt = corpus.targets[tname]
            hw = frozenset(tgt.flags)
            cts = [ct for ct in tgt.ctypes
                   if select.valid_candidates(prim, tname, ct, hw)]
            if cts:
                row[tname] = cts
        matrix[name] = row
    return matrix


def check_coverage(corpus) -> AnalysisReport:
    rep = AnalysisReport()
    all_targets = set(corpus.targets)
    declared_flags: set[str] = set()
    for tgt in corpus.targets.values():
        declared_flags |= set(tgt.flags)

    matrix = availability_matrix(corpus)
    for name in sorted(corpus.primitives):
        prim = corpus.primitives[name]
        subject = f"primitive:{name}"
        covered = set(matrix[name])

        if covered and covered != all_targets:
            rep.add("TSL020",
                    f"generatable for {sorted(covered)} but not "
                    f"{sorted(all_targets - covered)}",
                    subject=subject)

        if not prim.tests:
            rep.add("TSL021", "no testing: entries — the generated library "
                    "ships this primitive ungated", subject=subject)

        # flags hwprobe can never produce
        for i, d in enumerate(prim.definitions):
            unknown = set(d.flags) - declared_flags
            if unknown:
                rep.add("TSL022",
                        f"requires {sorted(unknown)}, declared by no SRU "
                        "document — dead on every probe result",
                        subject=subject, location=f"def[{i}]")

        # dead candidates: never selectable on any (target, ctype)
        reachable: set[int] = set()
        for tname in sorted(corpus.targets):
            tgt = corpus.targets[tname]
            hw = frozenset(tgt.flags)
            for ct in tgt.ctypes:
                cands = select.valid_candidates(prim, tname, ct, hw)
                if not cands:
                    continue
                if prim.bench is not None:
                    reachable.update(prim.definitions.index(c) for c in cands)
                else:
                    chosen = select.choose(prim, tname, ct, hw)
                    if chosen is not None:
                        reachable.add(prim.definitions.index(chosen.impl))
        for i, d in enumerate(prim.definitions):
            if i in reachable:
                continue
            if set(d.flags) - declared_flags:
                continue        # already TSL022 — don't double-report
            why = ("no bench: setup to overrule the flag heuristic"
                   if prim.bench is None else "never a valid candidate")
            rep.add("TSL023",
                    f"definition for {d.target_extension!r} is never "
                    f"selected on any (target, ctype); {why}",
                    subject=subject, location=f"def[{i}]")

        # ctype not offered by the definition's target
        for i, d in enumerate(prim.definitions):
            tgt = corpus.targets.get(d.target_extension)
            if tgt is None:
                continue        # unknown target is a validation error already
            extra = [ct for ct in d.ctypes if ct not in tgt.ctypes]
            if extra:
                rep.add("TSL024",
                        f"ctypes {extra} not offered by target "
                        f"{d.target_extension!r}",
                        subject=subject, location=f"def[{i}]")
    return rep
