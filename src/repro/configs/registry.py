"""Architecture registry: ``--arch <id>`` resolution for launch/ & benchmarks."""

from __future__ import annotations

import importlib

from .arch import ArchConfig

_MODULES = {
    "zamba2-7b": "zamba2_7b",
    "internvl2-2b": "internvl2_2b",
    "yi-34b": "yi_34b",
    "mistral-large-123b": "mistral_large_123b",
    "qwen3-14b": "qwen3_14b",
    "qwen1.5-0.5b": "qwen15_05b",
    "grok-1-314b": "grok1_314b",
    "arctic-480b": "arctic_480b",
    "whisper-tiny": "whisper_tiny",
    "rwkv6-7b": "rwkv6_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG
