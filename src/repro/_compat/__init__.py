"""Compatibility shims for dependencies the runtime may lack.

Import the submodule for the dependency you need gated; each registers
itself in ``sys.modules`` only when the real package is absent.
"""
