"""Serving launcher: batched prefill + decode with continuous-batching-lite
(finished sequences are replaced from a request queue between decode steps).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
        --batch 4 --prompt-len 32 --gen-len 32 --requests 8
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.nn.model import build_model


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # serving different archs in one process: drop jit caches so recycled
    # function ids from a previous model cannot alias stale executables
    jax.clear_caches()
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    max_len = args.prompt_len + args.gen_len

    rng = np.random.default_rng(args.seed)
    pending = [rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]
    completed = 0
    total_tokens = 0

    # jit the per-model callables directly (NOT same-source lambdas: two
    # serve_main calls in one process would otherwise collide in jit's
    # code-object keyed cache)
    prefill = jax.jit(model.prefill, static_argnums=(2,))
    decode = jax.jit(model.decode_step)

    def make_batch(prompts):
        batch = {"tokens": jnp.asarray(np.stack(prompts))}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (len(prompts), cfg.vision_prefix, cfg.d_model), cfg.dtype)
        if cfg.family == "audio":
            batch["audio_embeds"] = jnp.zeros(
                (len(prompts), args.prompt_len, cfg.d_model), cfg.dtype)
        return batch

    t0 = time.perf_counter()
    outputs = []
    while pending:
        wave, pending = pending[:args.batch], pending[args.batch:]
        n_real = len(wave)                            # requests actually served
        while len(wave) < args.batch:                 # pad the wave
            wave.append(np.zeros(args.prompt_len, np.int32))
        logits, state = prefill(params, make_batch(wave), max_len)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        gen = [tok]
        for i in range(args.gen_len - 1):
            pos = jnp.int32(args.prompt_len + i)
            logits, state = decode(params, state, tok.astype(jnp.int32), pos)
            tok = jnp.argmax(logits, axis=-1)[:, None]
            gen.append(tok)
        # padded wave slots are compute overhead, not served traffic: count
        # only real requests or decode_tokens_per_s overstates throughput
        outputs.append(
            np.concatenate([np.asarray(g) for g in gen], axis=1)[:n_real])
        completed += n_real
        total_tokens += n_real * args.gen_len
    wall = time.perf_counter() - t0
    result = {
        "arch": cfg.name,
        "requests": completed,
        "decode_tokens_per_s": total_tokens / wall,
        "sample_output": outputs[0][0][:8].tolist(),
    }
    print("[serve] done:", json.dumps(result))
    return result


if __name__ == "__main__":
    main()
