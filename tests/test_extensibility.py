"""Extensibility case study (paper §5.3, FPGA -> here: a simulated Trainium-
like target added purely via UPD files in an extra search path) + the LOC
accounting the paper reports (19 schema/template lines -> here ZERO core
lines; ~100 UPD lines -> generated library)."""

import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

TRN_TARGET = """\
---
name: "trn_sim"
vendor: "sim"
description: "Simulated Trainium-like target: NKI-ish tile geometry."
lscpu_flags: ["xla", "trn", "pe_array"]
ctypes: ["float32", "bfloat16"]
default_ctype: "float32"
lanes: 128
sublanes: 32
mxu: [128, 128]
vmem_bytes: 25165824
hbm_bytes: 34359738368
peak_flops_bf16: 9.5e+13
hbm_bw: 4.0e+11
ici_bw: 2.0e+10
ici_links: 4
interpret: false
runs_on_host: true
...
"""

TRN_PRIMS = """\
---
primitive_name: "trn_scale_add"
group: "trn"
brief: "saxpy-like fused op exercising the new target."
parameters:
  - {name: "a", ctype: "register"}
  - {name: "b", ctype: "register"}
  - {name: "alpha", ctype: "scalar", default: "1.0"}
returns: {ctype: "register"}
definitions:
  - target_extension: "trn_sim"
    ctype: ["float32", "bfloat16"]
    lscpu_flags: ["xla", "trn"]
    implementation: |
      return a * jnp.asarray(alpha, a.dtype) + b
testing:
  - name: "saxpy"
    requires: []
    implementation: |
      a = ctx.array((4, 8), ctype)
      b = ctx.array((4, 8), ctype)
      ctx.allclose(ops.trn_scale_add(a, b, alpha=2.0),
                   2 * np.asarray(a, np.float64) + np.asarray(b, np.float64),
                   ctype, scale=4.0)
...
"""


@pytest.fixture(scope="module")
def trn_upd(tmp_path_factory):
    root = tmp_path_factory.mktemp("trn_upd")
    (root / "targets").mkdir()
    (root / "primitives").mkdir()
    (root / "targets" / "trn_sim.yaml").write_text(TRN_TARGET)
    (root / "primitives" / "trn.yaml").write_text(TRN_PRIMS)
    return root


def test_new_target_via_pure_data(trn_upd):
    """Integrating a brand-new target requires ZERO generator-code changes —
    stronger than the paper's 19-LOC schema/template change."""
    from repro.core import load_library

    lib = load_library("trn_sim", upd_paths=(str(trn_upd),))
    assert lib.TARGET_NAME == "trn_sim"
    # existing portable primitives that list trn? none -> only trn group +
    # any multi-target prims; the new primitive must exist and work:
    a = jnp.ones((2, 4), jnp.float32)
    b = jnp.zeros((2, 4), jnp.float32)
    out = lib.ops.trn_scale_add(a, b, alpha=3.0)
    np.testing.assert_allclose(np.asarray(out), 3.0)


def test_existing_primitives_can_target_new_sru(trn_upd, tmp_path):
    """Point an EXISTING primitive at the new target from the extension path
    (the paper's '7 primitives, 100 LOC' FPGA exercise)."""
    extra = tmp_path / "upd2"
    (extra / "targets").mkdir(parents=True)
    (extra / "primitives").mkdir()
    (extra / "targets" / "trn_sim.yaml").write_text(TRN_TARGET)
    (extra / "primitives" / "trn.yaml").write_text(TRN_PRIMS + textwrap.dedent("""\
    ---
    primitive_name: "hadd_trn"
    group: "trn"
    brief: "hadd for the trn target (paper Fig 11 exercise)."
    parameters:
      - {name: "value", ctype: "register"}
    returns: {ctype: "register"}
    definitions:
      - target_extension: "trn_sim"
        ctype: ["float32"]
        lscpu_flags: ["xla", "trn", "pe_array"]
        implementation: |
          n = value.shape[-1]
          p = 1 << max(1, (n - 1)).bit_length()
          if p != n:
              value = jnp.pad(value, [(0, 0)] * (value.ndim - 1) + [(0, p - n)])
          while value.shape[-1] > 1:
              half = value.shape[-1] // 2
              value = value[..., :half] + value[..., half:]
          return value[..., 0]
    testing:
      - name: "sums"
        requires: []
        implementation: |
          v = ctx.array((3, 20), ctype, -2, 2)
          ctx.allclose(ops.hadd_trn(v), np.asarray(v, np.float64).sum(-1), ctype, scale=32.0)
    ...
    """))
    from repro.core import load_library

    lib = load_library("trn_sim", upd_paths=(str(extra),))
    v = jnp.asarray(np.arange(20, dtype=np.float32))
    assert float(lib.ops.hadd_trn(v)) == float(np.arange(20).sum())


def test_gpu_pallas_target_is_pure_data():
    """ISSUE 2 tentpole proof: the FIFTH in-tree target (gpu_pallas, Triton
    dialect) generates its library purely from UPD documents — the generator
    core contains no mention of it whatsoever."""
    from repro.core import GenConfig, generate_library

    pkg_dir, res = generate_library(GenConfig(target="gpu_pallas"), force=True)
    assert res is not None
    # broad coverage: every portable primitive plus the Triton specializations
    assert len(res.selection) >= 30
    man_flags = {name: sels["float32"].impl.flags
                 for name, sels in res.selection.items() if "float32" in sels}
    # Triton-dialect definitions win selection where they exist (more matched
    # hardware flags than the portable xla implementation)
    for prim in ("rmsnorm", "softmax", "hadd"):
        assert "triton" in man_flags[prim], (prim, man_flags[prim])
    assert man_flags["matmul"] == ("xla",)               # portable fallback


def test_gpu_pallas_needed_zero_core_changes():
    """Structural zero-core-diff proof: no file under core/ knows the
    gpu_pallas target or the Triton dialect exists."""
    from pathlib import Path

    import repro.core

    core_dir = Path(repro.core.__file__).parent
    offenders = []
    for f in sorted(core_dir.rglob("*")):
        if f.suffix not in (".py", ".j2") or not f.is_file():
            continue
        src = f.read_text()
        if "gpu_pallas" in src or "triton" in src.lower():
            offenders.append(f.name)
    assert not offenders, offenders


def test_gpu_pallas_library_importable_on_host():
    """runs_on_host:false targets still produce an importable package (the
    cross-generation story: generate here, execute on the real accelerator)."""
    from repro.core import load_library

    lib = load_library("gpu_pallas")
    assert lib.TARGET_NAME == "gpu_pallas"
    assert lib.TARGET.has("gpu", "triton")
    assert lib.TARGET.lanes == 32                        # warp geometry, not TPU tiles
    assert "rmsnorm" in lib.PRIMITIVES and "flash_attention" in lib.PRIMITIVES


def test_loc_accounting(trn_upd):
    """Paper §5.3 metric: UPD lines written vs generated library lines."""
    from repro.core import GenConfig, generate_library

    upd_lines = sum(len(f.read_text().splitlines())
                    for f in trn_upd.rglob("*.yaml"))
    pkg_dir, _ = generate_library(
        GenConfig(target="trn_sim", upd_paths=(str(trn_upd),)), force=True)
    gen_lines = sum(len(f.read_text().splitlines())
                    for f in pkg_dir.rglob("*.py"))
    assert upd_lines < 120
    assert gen_lines > upd_lines          # generation amplifies
