"""Golden-findings fixtures (ISSUE 6 satellite): a seeded-violation UPD
mini-corpus pushed through the real CLI must produce exactly the expected
TSL0xx codes and a nonzero exit, proving the analyzer catches what it claims
to catch end-to-end (loader -> validate -> analyze -> report -> exit code).
"""

import json

import pytest

from repro.core import cli

MINI_TARGET = """\
---
name: "minitgt"
vendor: "test"
description: "Fixture SRU for TSL-Check golden tests."
lscpu_flags: ["xla", "mini"]
ctypes: ["float32"]
default_ctype: "float32"
lanes: 128
sublanes: 8
mxu: [128, 128]
vmem_bytes: 16777216
hbm_bytes: 1073741824
peak_flops_bf16: 1.0e+12
hbm_bw: 1.0e+11
ici_bw: 1.0e+10
ici_links: 1
interpret: true
runs_on_host: true
...
"""

# each primitive seeds exactly one violation family
MINI_PRIMS = """\
---
primitive_name: "bad_cost"
group: "fixture"
brief: "cost formula references a symbol outside cost_shapes -> TSL012."
parameters:
  - {name: "x", ctype: "register"}
returns: {ctype: "register"}
cost_shapes: ["N"]
definitions:
  - target_extension: "minitgt"
    ctype: ["float32"]
    lscpu_flags: ["xla"]
    cost: {"flops": "2*N*QQ"}
    implementation: |
      return x
testing:
  - name: "t"
    requires: []
    implementation: |
      pass
...
---
primitive_name: "untested_prim"
group: "fixture"
brief: "no testing: entries -> TSL021."
parameters:
  - {name: "x", ctype: "register"}
returns: {ctype: "register"}
definitions:
  - target_extension: "minitgt"
    ctype: ["float32"]
    lscpu_flags: ["xla"]
    implementation: |
      return x
...
---
primitive_name: "bad_np"
group: "fixture"
brief: "host numpy inside the traced body -> TSL041."
parameters:
  - {name: "x", ctype: "register"}
returns: {ctype: "register"}
definitions:
  - target_extension: "minitgt"
    ctype: ["float32"]
    lscpu_flags: ["xla"]
    implementation: |
      return np.tanh(x)
testing:
  - name: "t"
    requires: []
    implementation: |
      pass
...
---
primitive_name: "bad_tile"
group: "fixture"
brief: "misaligned BlockSpec + unguarded grid remainder -> TSL030/TSL031."
parameters:
  - {name: "x", ctype: "register"}
  - {name: "n", ctype: "int", attributes: ["keyword_only"]}
returns: {ctype: "register"}
definitions:
  - target_extension: "minitgt"
    ctype: ["float32"]
    lscpu_flags: ["xla"]
    implementation: |
      spec = pl.BlockSpec((8, 96), lambda i: (i, 0))
      grid = (n // 7,)
      return x
testing:
  - name: "t"
    requires: []
    implementation: |
      pass
...
---
primitive_name: "bad_page"
group: "fixture"
brief: "page-size candidate misaligned to minitgt sublanes -> TSL033."
parameters:
  - {name: "pool", ctype: "register"}
  - {name: "table", ctype: "register"}
returns: {ctype: "register"}
serve: {page_size: 10, page_sizes: [10, 64]}
definitions:
  - target_extension: "minitgt"
    ctype: ["float32"]
    lscpu_flags: ["xla"]
    implementation: |
      return pool
testing:
  - name: "t"
    requires: []
    implementation: |
      pass
...
---
primitive_name: "bad_block"
group: "fixture"
brief: "fused block_k candidate incompatible with a page-size candidate -> TSL033."
parameters:
  - {name: "q", ctype: "register"}
  - {name: "pool", ctype: "register"}
  - {name: "tables", ctype: "register"}
returns: {ctype: "register"}
serve: {block_k: 48, block_ks: [48, 64]}
definitions:
  - target_extension: "minitgt"
    ctype: ["float32"]
    lscpu_flags: ["xla"]
    implementation: |
      return q
testing:
  - name: "t"
    requires: []
    implementation: |
      pass
...
"""


@pytest.fixture(scope="module")
def mini_upd(tmp_path_factory):
    root = tmp_path_factory.mktemp("tslcheck_upd")
    (root / "targets").mkdir()
    (root / "primitives").mkdir()
    (root / "targets" / "minitgt.yaml").write_text(MINI_TARGET)
    (root / "primitives" / "fixture.yaml").write_text(MINI_PRIMS)
    return root


@pytest.fixture(scope="module")
def golden(mini_upd, tmp_path_factory):
    """One CLI run shared by every assertion: (exit_code, parsed report)."""
    report = tmp_path_factory.mktemp("out") / "findings"
    rc = cli.main(["analyze", "--upd-path", str(mini_upd),
                   "--format", "json", "--fail-on", "error",
                   "--report", str(report)])
    data = json.loads(report.with_suffix(".json").read_text())
    md = report.with_suffix(".md").read_text()
    return rc, data, md


def _active(data, code):
    return [f for f in data["findings"]
            if f["code"] == code and not f["suppressed"] and not f["baselined"]]


def test_seeded_corpus_fails_the_error_gate(golden):
    rc, data, _ = golden
    assert rc != 0
    assert data["counts"]["error"] > 0


def test_bad_cost_symbol_is_tsl012(golden):
    _, data, _ = golden
    hits = _active(data, "TSL012")
    assert any(f["subject"] == "primitive:bad_cost" and "QQ" in f["message"]
               for f in hits)


def test_untested_primitive_is_tsl021(golden):
    _, data, _ = golden
    assert any(f["subject"] == "primitive:untested_prim"
               for f in _active(data, "TSL021"))


def test_traced_numpy_is_tsl041(golden):
    _, data, _ = golden
    hits = [f for f in _active(data, "TSL041")
            if f["subject"] == "primitive:bad_np"]
    assert hits and all(f["severity"] == "error" for f in hits)


def test_misaligned_blockspec_and_grid_are_tsl030_tsl031(golden):
    _, data, _ = golden
    t30 = [f for f in _active(data, "TSL030")
           if f["subject"] == "primitive:bad_tile"]
    t31 = [f for f in _active(data, "TSL031")
           if f["subject"] == "primitive:bad_tile"]
    assert t30 and "96" in t30[0]["message"]
    assert t31 and "n // 7" in t31[0]["message"]


def test_misaligned_page_size_is_tsl033(golden):
    # bad_page declares page_sizes [10, 64] against minitgt (sublanes=8):
    # 10 must fire, 64 must not — the check is per-candidate, per-target
    _, data, _ = golden
    hits = [f for f in _active(data, "TSL033")
            if f["subject"] == "primitive:bad_page"]
    assert hits and all(f["severity"] == "warn" for f in hits)
    assert any("candidate 10" in f["message"] for f in hits)
    assert not any("candidate 64" in f["message"] for f in hits)
    assert all(f["location"] == "target:minitgt" for f in hits)


def test_incompatible_block_k_is_tsl033(golden):
    # bad_block declares block_ks [48, 64] while bad_page publishes
    # page-size candidates [10, 64] on the same target: 48 is incompatible
    # with both (neither divides), 64 with 10 only — 64 x 64 must NOT fire
    _, data, _ = golden
    hits = [f for f in _active(data, "TSL033")
            if f["subject"] == "primitive:bad_block"]
    assert hits and all(f["severity"] == "warn" for f in hits)
    msgs = [f["message"] for f in hits]
    assert any("block_k candidate 48" in m and "page-size candidate 64" in m
               for m in msgs)
    assert any("block_k candidate 64" in m and "page-size candidate 10" in m
               for m in msgs)
    assert not any("block_k candidate 64" in m and "page-size candidate 64" in m
                   for m in msgs)
    assert all(f["location"] == "target:minitgt" for f in hits)


def test_priced_primitives_unreachable_on_new_target_is_tsl014(golden):
    # the fixture target offers no attention_decode/... definitions, so the
    # serving cost guarantee cannot hold there -- exactly what TSL014 states
    _, data, _ = golden
    hits = _active(data, "TSL014")
    assert any(f["location"] == "target:minitgt" for f in hits)
    # the shipped targets stay fully priced even with the fixture mixed in
    assert all(f["location"] == "target:minitgt" for f in hits)


def test_markdown_report_groups_by_code(golden):
    _, _, md = golden
    assert "# TSL-Check findings" in md
    assert "## `TSL012`" in md and "## `TSL041`" in md
    assert "primitive:bad_tile" in md
