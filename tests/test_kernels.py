"""Per-kernel validation: shape/dtype sweeps in Pallas interpret mode against
the pure-jnp ref.py oracles (task brief deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(42)


def _arr(shape, dt, lo=-2, hi=2):
    return jnp.asarray(RNG.uniform(lo, hi, shape), dtype=dt)


def _tol(dt):
    return dict(rtol=3e-2, atol=3e-2) if dt == "bfloat16" else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(4, 128), (7, 250), (33, 512), (2, 3, 257)])
@pytest.mark.parametrize("dt", ["float32", "bfloat16"])
def test_rmsnorm_sweep(shape, dt):
    from repro.kernels.rmsnorm import ops, ref

    x = _arr(shape, dt)
    w = _arr(shape[-1:], dt)
    got = ops.rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@pytest.mark.parametrize("b,h,kh,sq,sk,d,causal", [
    (1, 2, 2, 128, 128, 64, True),
    (2, 4, 2, 96, 160, 32, True),      # GQA + padding + ends alignment
    (1, 2, 1, 64, 64, 64, False),      # MQA non-causal
    (1, 8, 4, 200, 72, 16, True),      # sq > sk
])
@pytest.mark.parametrize("dt", ["float32", "bfloat16"])
def test_flash_attention_sweep(b, h, kh, sq, sk, d, causal, dt):
    from repro.kernels.flash_attention import ops, ref

    q = _arr((b, h, sq, d), dt, -1, 1)
    k = _arr((b, kh, sk, d), dt, -1, 1)
    v = _arr((b, kh, sk, d), dt, -1, 1)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@pytest.mark.parametrize("n", [64, 1000, 4096, 100_000])
@pytest.mark.parametrize("dt", ["float32", "int32"])
def test_range_count_sweep(n, dt):
    from repro.kernels.range_count import ops, ref

    d = _arr((n,), dt, 0, 100)
    got = int(ops.range_count(d, 5.0 if dt == "float32" else 5,
                              15.0 if dt == "float32" else 15, interpret=True))
    want = int(ref.range_count(d, 5 if dt == "int32" else 5.0,
                               15 if dt == "int32" else 15.0))
    assert got == want


@pytest.mark.parametrize("shape,n", [((13,), 8), ((100,), 32), ((4, 5), 16)])
def test_to_integral_sweep(shape, n):
    from repro.kernels.to_integral import ops, ref

    m = jnp.asarray(RNG.uniform(size=shape + (n,)) > 0.4)
    got = ops.to_integral(m, interpret=True)
    want = ref.to_integral(m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", [(3, 64), (10, 1000), (2, 2, 4096)])
@pytest.mark.parametrize("dt", ["float32", "bfloat16"])
def test_hadd_sweep(shape, dt):
    from repro.kernels.hadd import ops, ref

    v = _arr(shape, dt, -1, 1)
    got = ops.hadd(v, interpret=True)
    want = ref.hadd(v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2 if dt == "bfloat16" else 1e-4,
                               atol=5e-2 if dt == "bfloat16" else 1e-4)


@pytest.mark.parametrize("shape", [(5, 64), (19, 300), (2, 3, 129)])
@pytest.mark.parametrize("dt", ["float32", "bfloat16"])
def test_softmax_sweep(shape, dt):
    from repro.kernels.softmax import ops, ref

    x = _arr(shape, dt, -6, 6)
    got = ops.softmax(x, interpret=True)
    want = ref.softmax(x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))
    np.testing.assert_allclose(np.asarray(got, np.float32).sum(-1), 1.0,
                               rtol=3e-2)


@pytest.mark.parametrize("shape", [(9, 64), (3, 7, 128)])
@pytest.mark.parametrize("dt", ["float32", "bfloat16"])
def test_swiglu_sweep(shape, dt):
    from repro.kernels.swiglu import ops, ref

    g, u = _arr(shape, dt), _arr(shape, dt)
    got = ops.swiglu(g, u, interpret=True)
    want = ref.swiglu(g, u)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@pytest.mark.parametrize("t,chunk", [(17, 32), (64, 32), (130, 64)])
def test_ssd_chunked_vs_scan(t, chunk):
    from repro.kernels.ssd import ops, ref

    B, H, P, N = 2, 3, 8, 4
    x = _arr((B, t, H, P), "float32", -1, 1)
    a = jnp.asarray(RNG.uniform(0.8, 0.999, (B, t, H)), jnp.float32)
    b = _arr((B, t, N), "float32", -1, 1)
    c = _arr((B, t, N), "float32", -1, 1)
    y1, h1 = ref.ssd_scan(x, a, b, c)
    y2, h2 = ops.ssd_chunked(x, a, b, c, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t,chunk", [(16, 16), (50, 32), (96, 32)])
def test_wkv6_chunked_vs_scan(t, chunk):
    from repro.kernels.wkv6 import ops, ref

    B, H, K, V = 2, 2, 8, 8
    r = _arr((B, t, H, K), "float32", -1, 1)
    k = _arr((B, t, H, K), "float32", -1, 1)
    v = _arr((B, t, H, V), "float32", -1, 1)
    w = jnp.asarray(RNG.uniform(0.7, 0.999, (B, t, H, K)), jnp.float32)
    u = _arr((H, K), "float32", -1, 1)
    y1, s1 = ref.wkv6_scan(r, k, v, w, u)
    y2, s2 = ops.wkv6_chunked(r, k, v, w, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_flash_attention_grad_matches_ref():
    """Backward pass through the kernel (interpret) vs oracle — training uses
    the kernel, so d/dq must agree."""
    from repro.kernels.flash_attention import ops, ref

    q = _arr((1, 2, 64, 32), "float32", -1, 1)
    k = _arr((1, 2, 64, 32), "float32", -1, 1)
    v = _arr((1, 2, 64, 32), "float32", -1, 1)

    def f_kernel(q):
        return jnp.sum(ops.flash_attention(q, k, v, causal=True, block_q=32,
                                           block_k=32, interpret=True) ** 2)

    def f_ref(q):
        return jnp.sum(ref.attention(q, k, v, causal=True) ** 2)

    g1 = jax.grad(f_kernel)(q)
    g2 = jax.grad(f_ref)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-3, atol=2e-3)
