"""Mamba2 block (zamba2 backbone) on TSL seq primitives.

Block: in_proj -> [z | x | B | C | dt] -> causal_conv1d(x) -> SSD -> gated
rmsnorm -> out_proj. Scalar-per-head decay a = exp(-exp(A_log)·softplus(dt)),
input scaled by dt (the SSD discretization).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.tsl_api import ops as tsl

from .common import dense_init, split_keys


def dims(cfg):
    d_in = cfg.d_inner_mult * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state, cfg.ssm_head_dim


def init_mamba2(key, cfg, dtype):
    d = cfg.d_model
    d_in, nh, n, p_dim = dims(cfg)
    ks = split_keys(key, 4)
    proj_out = 2 * d_in + 2 * n + nh
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, d_in), dtype, scale=0.5),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "gate_norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], (d_in, d), dtype),
    }


def _split_proj(zxbcdt, cfg):
    d_in, nh, n, _ = dims(cfg)
    z = zxbcdt[..., :d_in]
    x = zxbcdt[..., d_in:2 * d_in]
    b = zxbcdt[..., 2 * d_in:2 * d_in + n]
    c = zxbcdt[..., 2 * d_in + n:2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n:]
    return z, x, b, c, dt


def _discretize(p, dt_raw, x, cfg):
    """-> (a (B,T,H) decay, x_scaled (B,T,H,P))."""
    _, nh, _, p_dim = dims(cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)
    xh = x.reshape(*x.shape[:-1], nh, p_dim)
    x_scaled = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    return a.astype(x.dtype), x_scaled, xh


def mamba2_forward(p, x_seq, cfg, *, h0=None, conv_prev=None):
    """x_seq: (B,T,D) -> (y (B,T,D), (h_final, conv_tail))."""
    bsz, t, d = x_seq.shape
    d_in, nh, n, p_dim = dims(cfg)
    zxbcdt = tsl.matmul(x_seq, p["in_proj"])
    z, xr, b, c, dt_raw = _split_proj(zxbcdt, cfg)
    if conv_prev is not None:
        xr_in = jnp.concatenate([conv_prev, xr], axis=1)
        xc = tsl.causal_conv1d(xr_in, p["conv_w"])[:, conv_prev.shape[1]:]
    else:
        xc = tsl.causal_conv1d(xr, p["conv_w"])
    xc = tsl.silu(xc)
    a, x_scaled, xh = _discretize(p, dt_raw, xc, cfg)
    y, h_final = tsl.ssd_scan(x_scaled, a, b, c, h0=h0)
    y = y + p["D_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(bsz, t, d_in)
    y = tsl.rmsnorm(y * tsl.silu(z), p["gate_norm_w"], eps=cfg.norm_eps)
    conv_tail = xr[:, -(cfg.conv_width - 1):] if cfg.conv_width > 1 else None
    return tsl.matmul(y, p["out_proj"]), (h_final, conv_tail)


def mamba2_decode(p, x_t, cfg, h, conv_cache):
    """One step. x_t (B,1,D); h (B,H,P,N) f32; conv_cache (B,KW-1,d_in)."""
    bsz, _, d = x_t.shape
    d_in, nh, n, p_dim = dims(cfg)
    zxbcdt = tsl.matmul(x_t, p["in_proj"])
    z, xr, b, c, dt_raw = _split_proj(zxbcdt, cfg)
    window = jnp.concatenate([conv_cache, xr], axis=1)      # (B,KW,d_in)
    conv_cache = window[:, 1:]
    xc = jnp.sum(window.astype(jnp.float32)
                 * p["conv_w"].astype(jnp.float32)[None], axis=1, keepdims=True)
    xc = tsl.silu(xc.astype(x_t.dtype))
    a, x_scaled, xh = _discretize(p, dt_raw, xc, cfg)
    yt, h = tsl.ssd_decode(x_scaled[:, 0], a[:, 0], b[:, 0], c[:, 0], h)
    yt = yt + p["D_skip"][None, :, None].astype(yt.dtype) * xh[:, 0]
    yt = yt.reshape(bsz, 1, d_in)
    yt = tsl.rmsnorm(yt * tsl.silu(z), p["gate_norm_w"], eps=cfg.norm_eps)
    return tsl.matmul(yt, p["out_proj"]), h, conv_cache
