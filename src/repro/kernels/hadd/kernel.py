"""Pallas TPU kernel: horizontal add with explicit log2 adder tree.

This is the TPU-native rendering of the paper's Fig 11 FPGA adder tree: the
outer `stage` loop of Fig 11 becomes a Python-unrolled halving loop over VREG
lane groups inside one VMEM tile; cross-tile partial sums accumulate in f32
scratch across the sequential column grid (the paper's `#pragma unroll` has
no TPU analogue — unrolling happens at trace time, DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _tree_sum_last(x):
    """Explicit pairwise halving tree over a power-of-two last axis."""
    while x.shape[-1] > 1:
        half = x.shape[-1] // 2
        x = x[..., :half] + x[..., half:]
    return x


def _hadd_kernel(x_ref, o_ref, acc_scr, *, n_valid: int, bn: int):
    j = pl.program_id(1)
    ncols = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)                  # (bm, bn)
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(col < n_valid, x, 0.0)
    acc_scr[...] += _tree_sum_last(x)                    # (bm, 1)

    @pl.when(j == ncols - 1)
    def _finalize():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def hadd_2d(x2, *, n_valid: int, block_rows: int = 256, block_cols: int = 1024,
            interpret: bool = False):
    """x2: (rows, cols) with cols a power-of-two multiple of block_cols;
    returns (rows, 1) row sums."""
    rows, cols = x2.shape
    bm = min(block_rows, rows)
    bn = min(block_cols, cols)
    assert rows % bm == 0 and cols % bn == 0 and (bn & (bn - 1)) == 0
    return pl.pallas_call(
        functools.partial(_hadd_kernel, n_valid=n_valid, bn=bn),
        grid=(rows // bm, cols // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 1), x2.dtype),
        scratch_shapes=[pltpu.VMEM((bm, 1), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="tsl_hadd",
    )(x2)
