"""State surgery for continuous batching: slot-level access to a live
batched decode state.

Every decode family carries its state as a pytree of arrays with the request
(slot) axis at a family-specific position per leaf — KV caches put it at
axis 1 under the layer axis, zamba's grouped SSM states at axis 2 under the
(group, layer-in-group) axes, rwkv recurrent states at axis 1, encdec
cross-state at axis 1. The family module declares that knowledge once as a
``state_batch_axes(state)`` pytree of ints (same treedef as the state), and
the surgery itself lives on the ModelApi: ``Model.insert_slot`` writes a
freshly prefilled single-request state (slot axis of size 1) into one slot,
``Model.reset_slot`` zeroes a finished slot. Both are pure jnp
(``dynamic_update_slice_in_dim`` with a traced slot index), so an engine can
jit them once and admit into ANY slot without recompiling — the
jit-stable-shape property per-step continuous batching depends on.

This module provides the serving-side companions: reading a slot back out
(``take_slot``), host-side donor validation (``validate_donor``), and the
PAGED-memory building blocks: :class:`PageAllocator` (a refcounted free list
over fixed-size cache pages — the unit the paged store accounts HBM in) and
:class:`SlotPages` (one request's page list + fill). The device pools and
the gather/scatter through the ``cache_page_read/write`` UPD primitives live
in ``serve/paging.py``; this layer is pure host bookkeeping, so hypothesis
can drive it hard (no double-free, refcounts never negative, alloc/free
round-trips).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax


class PagesExhausted(RuntimeError):
    """No free page: the caller must evict/preempt or defer admission."""


class PageAllocator:
    """Refcounted free-list allocator over ``n_pages`` fixed-size pages.

    Pages are abstract ids (0..n_pages-1); the paged store maps id -> row
    offset ``id * page_size`` in every leaf pool. ``alloc`` hands out a page
    at refcount 1; ``retain`` adds a sharer (copy-on-write prefix sharing);
    ``release`` drops one reference and returns the page to the free list
    when the count hits zero. Double-free and retain-after-free raise
    instead of corrupting the pool."""

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"need at least one page, got {n_pages}")
        self.n_pages = int(n_pages)
        self._free = list(range(self.n_pages - 1, -1, -1))
        self._refs = [0] * self.n_pages

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def refcount(self, page: int) -> int:
        return self._refs[page]

    def alloc(self) -> int:
        if not self._free:
            raise PagesExhausted(f"all {self.n_pages} pages in use")
        page = self._free.pop()
        self._refs[page] = 1
        return page

    def _check(self, page: int) -> None:
        if not 0 <= page < self.n_pages:
            raise ValueError(f"page {page} outside pool of {self.n_pages}")

    def retain(self, page: int) -> None:
        self._check(page)
        if self._refs[page] <= 0:
            raise ValueError(f"retain of free page {page}")
        self._refs[page] += 1

    def release(self, page: int) -> None:
        self._check(page)
        if self._refs[page] <= 0:
            raise ValueError(f"double free of page {page}")
        self._refs[page] -= 1
        if self._refs[page] == 0:
            self._free.append(page)


@dataclass
class SlotPages:
    """One request's page list: ``pages[i]`` covers cache rows
    [i*page_size, (i+1)*page_size). ``n_shared`` leading pages are prefix-
    store pages held by reference (read-only until copy-on-write)."""

    pages: list[int] = field(default_factory=list)
    n_shared: int = 0
    fill: int = 0                   # real cache rows committed so far

    def covered_rows(self, page_size: int) -> int:
        return len(self.pages) * page_size


def take_slot(state, axes, slot: int):
    """Read slot ``slot`` back out as a single-request state (host-side
    inspection / tests). Keeps the slot axis with size 1, mirroring what
    ``Model.insert_slot`` expects as a donor."""

    def tk(leaf, ax):
        return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=ax)

    return jax.tree.map(tk, state, axes)


def assert_span_fits(pos, span: int, state_len: int) -> None:
    """Raise RuntimeError if any slot's span write [pos, pos+span) would
    overrun the state's row capacity.

    ``jax.lax.dynamic_update_slice`` CLAMPS an out-of-range start index
    instead of erroring, so a verify slab launched too close to the end of
    the cache would silently slide backwards and rewrite the last committed
    rows — the worst kind of corruption, visible only as wrong tokens much
    later. The engine sizes its slot table with ``k_max`` headroom rows
    beyond max_len precisely so this never fires; this guard keeps the
    invariant loud if a future scheduling change breaks it."""
    import numpy as np

    pos = np.asarray(pos)
    hi = int(pos.max()) + int(span) if pos.size else 0
    if hi > state_len:
        raise RuntimeError(
            f"span write [{int(pos.max())}, {hi}) overruns the state's "
            f"{state_len} rows — dynamic_update_slice would clamp and "
            f"corrupt committed cache rows")


def validate_donor(state, donor, axes) -> None:
    """Raise ValueError unless ``donor`` is shape-compatible with one slot of
    ``state``: identical leaves except the slot axis, which must be 1.

    Catches the classic continuous-batching foot-guns before they become an
    XLA shape error deep in a jitted insert — e.g. a prefill that padded its
    KV cache to a different max_len than the engine's slot table, or an
    encdec donor whose encoder length differs from the engine's.
    """
    s_leaves, s_def = jax.tree.flatten(state)
    d_leaves, d_def = jax.tree.flatten(donor)
    a_leaves, _ = jax.tree.flatten(axes)
    if s_def != d_def:
        raise ValueError(
            f"donor state tree does not match batched state tree: "
            f"{d_def} vs {s_def}")
    for s, d, ax in zip(s_leaves, d_leaves, a_leaves):
        want = list(s.shape)
        want[ax] = 1
        if list(d.shape) != want:
            raise ValueError(
                f"donor leaf {d.shape} incompatible with batched leaf "
                f"{s.shape} (slot axis {ax}; expected {tuple(want)})")
