"""Speculative decoding demo: the slot engine drafts ahead with a free n-gram
prompt-lookup drafter and verifies every slot's proposal in ONE batched ragged
``attention_verify`` step, with per-slot speculation depth priced by the
generated library's cost channel.

Three claims, each asserted:

1. Mixed greedy AND sampled requests share one verify span — per-request
   ``temperature`` overrides coexist in a single batched step, and the greedy
   request's output is exactly what the plain (non-speculative) engine emits.
2. On a repetitive prompt the drafter earns its keep: accepted-token rate
   > 0 and the engine's per-slot decode steps per emitted token < 1.0.
3. ``fixed_k=0`` degrades to the ORIGINAL decode path, token-for-token —
   including the sampled request (same key-draw sequence).

    PYTHONPATH=src python examples/serve_speculative.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.serve import (Request, SamplingConfig, ServeEngine,  # noqa: E402
                         SpeculationConfig)

REPETITIVE = [5, 6, 7, 8] * 4   # prompt-lookup heaven: pure period-4 cycle


def requests(cfg):
    rnd = np.random.default_rng(0).integers(1, cfg.vocab, 8)
    return [
        # greedy request on a repetitive prompt: drafts should hit
        Request(rid="greedy-rep", tokens=np.array(REPETITIVE), gen_len=14),
        # sampled neighbour sharing the verify span (temperature override)
        Request(rid="sampled", tokens=rnd, gen_len=10, temperature=0.8),
        # third request exercises mid-stream slot reuse under speculation
        Request(rid="greedy-late", tokens=np.array(REPETITIVE[:7]),
                gen_len=8),
    ]


def run(cfg, speculation):
    jax.clear_caches()
    engine = ServeEngine(
        cfg, batch=2, max_len=48, admission=False, seed=0,
        sampling=SamplingConfig(temperature=0.0),   # default greedy
        speculation=speculation)
    return engine.run(requests(cfg))


def main():
    cfg = get_config("qwen1.5-0.5b").reduced()

    print("[example] plain decode (reference)")
    plain = run(cfg, None)

    print("[example] speculative decode: n-gram drafter, cost-priced depth")
    spec = run(cfg, SpeculationConfig(drafter="ngram", fixed_k=3))
    s = spec["spec"]
    print(f"[example]   drafted {s['drafted_tokens']}, accepted "
          f"{s['accepted_tokens']} (rate {s['accepted_rate']:.2f}), "
          f"mean accepted span {s['mean_accepted_span']:.2f}")
    print(f"[example]   slot-steps per emitted token: "
          f"{s['slot_steps_per_emitted_token']:.2f} (plain decode = 1.0)")
    print(f"[example]   accept by bucket: {s['accept_by_bucket']}")

    # 1. greedy outputs are bit-identical to plain decode, sampled neighbour
    #    and all — speculation is lossless
    for rid in ("greedy-rep", "greedy-late"):
        assert spec["outputs"][rid] == plain["outputs"][rid], rid
    # 2. the drafter found repetition: real acceptance, fewer slot-steps
    #    than emitted tokens
    assert s["accepted_rate"] > 0, s
    assert s["slot_steps_per_emitted_token"] < 1.0, s
    # only target-emitted tokens are billed as output
    for m in spec["per_request"]:
        assert m["tokens_out"] == len(spec["outputs"][m["rid"]]), m

    print("[example] k=0 degradation: original decode path, same key draws")
    k0 = run(cfg, SpeculationConfig(fixed_k=0))
    # 3. token-for-token identical INCLUDING the sampled request
    assert k0["outputs"] == plain["outputs"], "k=0 must match plain decode"
    assert k0["spec"]["verify_steps"] == 0, k0["spec"]
    print("[example]   k=0 outputs identical to plain decode "
          "(incl. sampled request)")

    print("[example] speculative serving demo OK")


if __name__ == "__main__":
    main()
