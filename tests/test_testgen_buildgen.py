"""Test-generation GPO (DAG/toposort/unsafe) + build-env GPO tests."""

import json
from pathlib import Path

from repro.core import GenConfig
from repro.core.model import (CorpusIR, GenerationResult, ImplDef, ParamDef,
                              PrimitiveDef, TargetDef, TestDef)
from repro.core.select import SelectGPO
from repro.core.testgen import TestGenGPO


def _target():
    return TargetDef(
        name="t", vendor="v", flags=("xla",), ctypes=("float32",),
        default_ctype="float32", lanes=128, sublanes=8, mxu=(128, 128),
        vmem_bytes=1, hbm_bytes=1, peak_flops_bf16=1.0, hbm_bw=1.0,
        ici_bw=1.0, ici_links=1)


def _prim(name, requires=(), tested=True):
    tests = (TestDef(name="t1", implementation="assert True",
                     requires=tuple(requires)),) if tested else ()
    return PrimitiveDef(
        name=name, group="g", brief="", parameters=(ParamDef("a"),),
        returns_ctype="register",
        definitions=(ImplDef(target_extension="t", ctypes=("float32",),
                             flags=("xla",), implementation="return a"),),
        tests=tests)


def _ctx(prims):
    corpus = CorpusIR.from_defs(targets={"t": _target()},
                                primitives={p.name: p for p in prims})
    ctx = GenerationResult(config=GenConfig(target="t", package_name="pkg"),
                           corpus=corpus)
    SelectGPO().run(ctx)
    return ctx


def test_topological_order():
    ctx = _ctx([_prim("c", requires=("b",)), _prim("b", requires=("a",)),
                _prim("a")])
    TestGenGPO().run(ctx)
    order = ctx.meta["test_order"]
    assert order.index("a") < order.index("b") < order.index("c")


def test_cycle_detected():
    ctx = _ctx([_prim("a", requires=("b",)), _prim("b", requires=("a",))])
    TestGenGPO().run(ctx)
    assert any("cycle" in e for e in ctx.errors)


def test_unsafe_marking():
    """Paper §4.1: dependency on an untested primitive => unsafe warning."""
    ctx = _ctx([_prim("a", tested=False), _prim("b", requires=("a",))])
    TestGenGPO().run(ctx)
    assert any("UNSAFE" in w for w in ctx.warnings)
    gen = next(f for f in ctx.files if f.relpath.endswith("test_generated.py"))
    assert "unsafe test" in gen.content


def test_generated_file_contains_tests_in_order():
    ctx = _ctx([_prim("beta", requires=("alpha",)), _prim("alpha")])
    TestGenGPO().run(ctx)
    gen = next(f for f in ctx.files if f.relpath.endswith("test_generated.py"))
    assert gen.content.index("test_alpha__t1") < gen.content.index("test_beta__t1")


def test_manifest_records_selection_provenance(lib_cpu):
    man = json.loads((Path(lib_cpu.__file__).parent / "_manifest.json").read_text())
    assert man["generator"] == "TSLGen-JAX"
    assert man["target"] == "cpu_xla"
    # every generated primitive has per-ctype provenance with scores
    hadd = man["primitives"]["hadd"]["float32"]
    assert {"score", "loc", "is_native", "candidates",
            "selected_by", "required_flags"} <= set(hadd)
    # file list covers the real files
    pkg = Path(lib_cpu.__file__).parent
    for f in man["files"]:
        assert (pkg / f).exists(), f


def test_interpret_target_selects_pallas_variants(lib_interp):
    """On the interpret SRU the Pallas definitions (more matched flags) win —
    the paper's 'most specialized implementation prevails'."""
    man = json.loads((Path(lib_interp.__file__).parent / "_manifest.json").read_text())
    rms = man["primitives"]["rmsnorm"]["float32"]
    assert "tpu" in rms["required_flags"]
    assert rms["candidates"] >= 2
    # whereas cpu picks the portable one
    import json as _json
    from pathlib import Path as _P

    # to_integral is a workaround on every target (paper Fig 6)
    ti = man["primitives"]["to_integral"]["float32"]
    assert ti["is_native"] is False
