"""Continuous-batching serving demo: mixed prompt AND generation lengths over
two decode families (attention KV cache vs RWKV recurrent state) through the
uniform slot/state-surgery contract — a freed slot is refilled before the
next decode step (watch the admission log), idle slots are never counted as
traffic, and cost-model admission + SLA accounting run on both.

    PYTHONPATH=src python examples/serve_batched.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.serve import Request, SamplingConfig, ServeEngine  # noqa: E402

# (prompt_len, gen_len) per request — deliberately staggered so slots free at
# different steps and the engine has to admit mid-stream
MIXED = [(8, 6), (8, 18), (16, 10), (16, 18), (8, 8), (16, 4)]


def serve_family(arch: str, *, batch: int, max_len: int, sla_ms: float) -> dict:
    cfg = get_config(arch).reduced()
    jax.clear_caches()     # two archs in one process: no stale jit aliases
    engine = ServeEngine(
        cfg, batch=batch, max_len=max_len,
        sampling=SamplingConfig(temperature=0.7, top_k=20), seed=0)
    rng = np.random.default_rng(0)
    requests = [
        Request(rid=f"{arch}-{i}",
                tokens=rng.integers(0, cfg.vocab, p).astype(np.int32),
                gen_len=g, sla_s=sla_ms / 1e3)
        for i, (p, g) in enumerate(MIXED)
    ]
    report = engine.run(requests)
    print(f"[example] {arch}: {report['requests']} served, "
          f"{report['decode_tokens_per_s']:,.0f} tok/s, "
          f"ttft {report['ttft_s_mean'] * 1e3:.1f}ms, "
          f"sla hit-rate {report['sla_hit_rate']}, "
          f"padded steady-state slot-steps {report['padded_slot_steps_steady']}")
    print(f"[example]   admission log: {report['admission_log']}")
    assert report["requests"] == len(MIXED), report
    assert report["padded_slot_steps_steady"] == 0, report
    mid_stream = [e for e in report["admission_log"] if e["step"] > 0]
    assert mid_stream, "expected at least one mid-stream admission"
    return report


def main():
    print("[example] serving qwen1.5-0.5b-reduced (KV-cache decode)")
    r1 = serve_family("qwen1.5-0.5b", batch=2, max_len=40, sla_ms=60_000)
    print("[example] serving rwkv6-7b-reduced (recurrent-state decode)")
    r2 = serve_family("rwkv6-7b", batch=2, max_len=40, sla_ms=60_000)
    print(f"[example] qwen decode t/s: {r1['decode_tokens_per_s']:,.0f}; "
          f"rwkv decode t/s: {r2['decode_tokens_per_s']:,.0f}")


if __name__ == "__main__":
    main()
