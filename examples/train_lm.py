"""End-to-end training driver (deliverable (b)): train a ~100M-param LM for a
few hundred steps on synthetic data, with checkpointing and restart.

    PYTHONPATH=src python examples/train_lm.py --preset m25 --steps 300
    PYTHONPATH=src python examples/train_lm.py --preset m100 --steps 200

Presets are qwen-family configs scaled to CPU-trainable sizes; the full
launcher (repro.launch.train) exposes every production knob — this example
drives it and plots the loss trajectory to experiments/.
"""

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get_config
from repro.data.pipeline import DataState, Prefetcher, SyntheticTokens
from repro.nn.model import build_model
from repro.train.optimizer import OptConfig
from repro.train.step import init_train_state, make_train_step

PRESETS = {
    # ~25M params: fast CPU loop
    "m25": dict(n_layers=6, d_model=512, n_heads=8, n_kv_heads=4,
                d_ff=1536, vocab=8192, head_dim=64),
    # ~110M params: the deliverable's "~100M model"
    "m100": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab=16384, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="m25", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config("qwen1.5-0.5b").replace(
        name=f"train-lm-{args.preset}", dtype="float32",
        tie_embeddings=False, qkv_bias=False, **PRESETS[args.preset])
    model = build_model(cfg)
    n_params = cfg.param_count()
    print(f"[example] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps of B={args.batch} S={args.seq}")

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0,))
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(0))

    source = SyntheticTokens(cfg.vocab, args.batch, args.seq, seed=0)
    prefetch = Prefetcher(source, DataState(), depth=2)
    losses = []
    t0 = time.perf_counter()
    for step in range(args.steps):
        batch = prefetch.get()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            tok_s = (step + 1) * args.batch * args.seq / dt
            print(f"  step {step:4d} loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} ({tok_s:,.0f} tok/s)")
    prefetch.stop()

    out = Path(__file__).resolve().parent.parent / "experiments" / \
        f"train_lm_{args.preset}.json"
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps({
        "preset": args.preset, "params": n_params, "steps": args.steps,
        "first_loss": losses[0], "final_loss": losses[-1],
        "loss_curve_every10": losses[::10],
        "tokens_per_s": args.steps * args.batch * args.seq
        / (time.perf_counter() - t0),
    }, indent=1))
    print(f"[example] loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"wrote {out}")
    assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
