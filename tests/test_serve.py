"""Serving-path regression: throughput accounting must count served requests,
not padded wave slots (padding is compute overhead, not traffic)."""

from repro.launch.serve import main


def test_serve_counts_only_real_requests():
    # 5 requests with batch 4 -> second wave is 1 real + 3 padded slots
    result = main(["--arch", "qwen1.5-0.5b", "--reduced", "--batch", "4",
                   "--prompt-len", "8", "--gen-len", "4", "--requests", "5"])
    assert result["requests"] == 5          # was 8 with padded-slot counting
    assert result["decode_tokens_per_s"] > 0
    assert len(result["sample_output"]) == 4
