"""Content-addressed artifact store for generated libraries AND
bench-selection winners (paper §4.2: benchmarking alongside adaptive variant
selection "should be integrated as an ongoing process").

Everything the generator emits is addressed by one :class:`CacheKey`:

    (UPD fingerprint, target, probed hardware flags, generator version,
     variant digest of the generation knobs)

so all artifact families share ONE invalidation rule — editing any UPD
document/template/generator source changes the fingerprint, plugging the
library into a different machine changes the probed hardware flags, and a
:data:`GENERATOR_VERSION` bump retires every artifact of the previous engine.
Bench winners deliberately omit the variant digest: a measured winner is a
property of (corpus, target, hardware), not of which package flavour asked
for it.

Layout under the cache root (default ``build/tsl/``)::

    pkg/<package>_<target>_<digest>/   generated library packages
    bench/<target>_<digest>.json       bench-selection winners
    index.json                         digest -> key components (introspection)
"""

from __future__ import annotations

import hashlib
import json
import shutil
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable

# Bump to retire every previously generated artifact (schema change in the
# generated package layout, selection semantics change, ...).
GENERATOR_VERSION = "2.0.0"


@dataclass(frozen=True)
class CacheKey:
    """The content address of one generation run."""

    fingerprint: str                     # UPD + template + generator-source hash
    target: str                          # SRU name
    hardware_flags: tuple[str, ...]      # probed/overridden flags, sorted
    generator_version: str               # GENERATOR_VERSION at generation time
    variant: str = ""                    # digest of generation knobs ("" = bench)

    def digest(self) -> str:
        h = hashlib.sha256()
        for part in (self.fingerprint, self.target, ",".join(self.hardware_flags),
                     self.generator_version, self.variant):
            h.update(part.encode())
            h.update(b"\0")
        return h.hexdigest()[:16]

    def as_dict(self) -> dict:
        return {
            "fingerprint": self.fingerprint,
            "target": self.target,
            "hardware_flags": list(self.hardware_flags),
            "generator_version": self.generator_version,
            "variant": self.variant,
            "digest": self.digest(),
        }

    def without_variant(self) -> "CacheKey":
        """The bench-winner address shared by all package variants."""
        return CacheKey(self.fingerprint, self.target, self.hardware_flags,
                        self.generator_version, "")


def variant_digest(config) -> str:
    """Digest of the generation knobs that change the package *content*
    beyond (corpus, target, hardware)."""
    h = hashlib.sha256(repr((
        sorted(config.only) if config.only else None,
        config.emit_tests, config.emit_docs, config.emit_build,
        config.use_bench_selection, config.package_name,
    )).encode())
    return h.hexdigest()[:8]


class ArtifactCache:
    """Filesystem-backed store; one instance per cache root."""

    def __init__(self, root: Path | str):
        self.root = Path(root)

    # -- layout --------------------------------------------------------------

    @property
    def package_root(self) -> Path:
        """Importable package directory (this path goes on ``sys.path``)."""
        return self.root / "pkg"

    @property
    def bench_root(self) -> Path:
        return self.root / "bench"

    def package_name(self, base: str, key: CacheKey) -> str:
        return f"{base}_{key.target}_{key.digest()[:10]}"

    def package_dir(self, name: str) -> Path:
        return self.package_root / name

    # -- generated packages ---------------------------------------------------

    def lookup(self, name: str) -> Path | None:
        """Committed package dir for ``name``, or None (partial writes — no
        ``_manifest.json`` stamp yet — count as misses)."""
        d = self.package_dir(name)
        return d if (d / "_manifest.json").exists() else None

    def commit(self, name: str, key: CacheKey, files: Iterable) -> Path:
        """Write a generated file set as package ``name`` and stamp it."""
        pkg_dir = self.package_dir(name)
        pkg_dir.mkdir(parents=True, exist_ok=True)
        for f in files:
            out = pkg_dir / f.relpath
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(f.content)
        (pkg_dir / "_cache_key.json").write_text(
            json.dumps(key.as_dict(), indent=1))
        if not (pkg_dir / "_manifest.json").exists():
            # emit_build=False still needs the commit stamp
            (pkg_dir / "_manifest.json").write_text("{}")
        self._index_put(name, key)
        return pkg_dir

    # -- bench winners ---------------------------------------------------------

    def bench_path(self, key: CacheKey) -> Path:
        k = key.without_variant()
        return self.bench_root / f"{k.target}_{k.digest()}.json"

    def bench_load(self, key: CacheKey) -> dict:
        p = self.bench_path(key)
        if not p.exists():
            return {}
        try:
            return json.loads(p.read_text())
        except json.JSONDecodeError:
            return {}

    def bench_store(self, key: CacheKey, data: dict) -> Path:
        p = self.bench_path(key)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(data, indent=1))
        return p

    # -- index / maintenance ----------------------------------------------------

    @property
    def _index_path(self) -> Path:
        return self.root / "index.json"

    def _index(self) -> dict:
        if not self._index_path.exists():
            return {}
        try:
            return json.loads(self._index_path.read_text())
        except json.JSONDecodeError:
            return {}

    def _index_put(self, name: str, key: CacheKey) -> None:
        idx = self._index()
        idx[name] = key.as_dict()
        self.root.mkdir(parents=True, exist_ok=True)
        self._index_path.write_text(json.dumps(idx, indent=1))

    def stats(self) -> dict:
        pkgs = sorted(p.name for p in self.package_root.iterdir()
                      if p.is_dir()) if self.package_root.is_dir() else []
        benches = sorted(p.name for p in self.bench_root.glob("*.json")) \
            if self.bench_root.is_dir() else []
        return {
            "root": str(self.root),
            "packages": pkgs,
            "bench_entries": benches,
            "index": self._index(),
        }

    def clear(self) -> int:
        """Drop every cached artifact. Returns number of entries removed."""
        n = 0
        for sub in (self.package_root, self.bench_root):
            if sub.is_dir():
                n += sum(1 for _ in sub.iterdir())
                shutil.rmtree(sub)
        if self._index_path.exists():
            self._index_path.unlink()
        return n

    def gc(self, max_age_days: float, *, now: float | None = None) -> int:
        """Age-based eviction: drop packages and bench entries whose artifacts
        were last written more than ``max_age_days`` ago. Recently re-generated
        (touched) artifacts survive; the index is pruned to match. Returns the
        number of entries removed — ``stats``/``clear`` semantics unchanged."""
        import time

        cutoff = (now if now is not None else time.time()) \
            - max_age_days * 86400.0
        removed = 0
        idx = self._index()
        if self.package_root.is_dir():
            for pkg in list(self.package_root.iterdir()):
                if not pkg.is_dir():
                    continue
                stamp = pkg / "_cache_key.json"
                mtime = (stamp if stamp.exists() else pkg).stat().st_mtime
                if mtime < cutoff:
                    shutil.rmtree(pkg)
                    idx.pop(pkg.name, None)
                    removed += 1
        if self.bench_root.is_dir():
            for bench in list(self.bench_root.glob("*.json")):
                if bench.stat().st_mtime < cutoff:
                    bench.unlink()
                    removed += 1
        if removed and self._index_path.exists():
            self._index_path.write_text(json.dumps(idx, indent=1))
        return removed
