"""Implementation-body safety lint (TSL04x).

UPD ``implementation:``/``helpers:`` blocks are exec'd into the generated
library and traced under ``jit`` — they must be pure device code. This
analyzer walks the stage-1-rendered bodies (see :mod:`.render`) and forbids:

* **TSL041** — host numpy (``np.``/``numpy.``) inside a *function* body.
  Module-level numpy in ``helpers:`` (host constant tables built once at
  import) is legitimate; inside a traced function it either fails to trace
  or silently falls back to host execution.
* **TSL042** — I/O and host side effects: ``print``/``open``/``input`` calls,
  ``os``/``sys``/``subprocess`` usage anywhere in the body.
* **TSL043** — host callback primitives (``pure_callback``, ``io_callback``,
  ``debug.callback``) — the generated TSL must stay device-only.
* **TSL044** — nondeterminism: ``time.*``, ``random.*``, ``np.random.*``.
  (``jax.random`` with explicit keys is deterministic and exempt.)
"""

from __future__ import annotations

import ast

from .findings import AnalysisReport
from .render import RenderedBody

_NUMPY_NAMES = {"np", "numpy"}
_IO_CALLS = {"print", "open", "input"}
_IO_MODULES = {"os", "sys", "subprocess", "shutil", "socket"}
_CALLBACKS = {"pure_callback", "io_callback"}
_NONDET_MODULES = {"time", "random"}


def _in_function(tree: ast.Module) -> set[int]:
    """ids of every node nested inside some function definition."""
    inside: set[int] = set()
    for fn in ast.walk(tree):
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            for sub in ast.walk(fn):
                if sub is not fn:
                    inside.add(id(sub))
    return inside


def check_body(rb: RenderedBody) -> AnalysisReport:
    rep = AnalysisReport()
    subject = f"primitive:{rb.primitive}"

    def loc(node: ast.AST) -> str:
        return f"def[{rb.def_index}] {rb.target} line {node.lineno}"

    tree = rb.tree
    assert tree is not None
    inside = _in_function(tree)
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if node.id in _NUMPY_NAMES and id(node) in inside:
                rep.add("TSL041",
                        f"host numpy ({node.id}.*) in a traced function — "
                        "use jnp",
                        subject=subject, location=loc(node))
            elif node.id in _IO_MODULES:
                rep.add("TSL042", f"host module {node.id!r} used",
                        subject=subject, location=loc(node))
            elif node.id in _NONDET_MODULES:
                rep.add("TSL044", f"nondeterministic module {node.id!r} used",
                        subject=subject, location=loc(node))
        elif isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in _IO_CALLS:
                rep.add("TSL042", f"{f.id}() call in an implementation body",
                        subject=subject, location=loc(node))
        elif isinstance(node, ast.Attribute):
            if node.attr in _CALLBACKS:
                rep.add("TSL043", f"{node.attr} punches through the compiled "
                        "graph", subject=subject, location=loc(node))
            elif node.attr == "callback" and isinstance(
                    node.value, (ast.Name, ast.Attribute)) and (
                    (isinstance(node.value, ast.Name)
                     and node.value.id == "debug")
                    or (isinstance(node.value, ast.Attribute)
                        and node.value.attr == "debug")):
                rep.add("TSL043", "debug.callback punches through the "
                        "compiled graph", subject=subject, location=loc(node))
            elif node.attr == "random" and isinstance(node.value, ast.Name) \
                    and node.value.id in _NUMPY_NAMES:
                rep.add("TSL044", f"{node.value.id}.random is host-side "
                        "nondeterminism", subject=subject, location=loc(node))
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            mod = getattr(node, "module", "") or ""
            names = {a.name.split(".")[0] for a in node.names} | \
                {mod.split(".")[0]}
            hit = names & (_IO_MODULES | _NONDET_MODULES)
            if hit and id(node) in inside:
                code = ("TSL042" if hit & _IO_MODULES else "TSL044")
                rep.add(code, f"import of {sorted(hit)} inside a traced "
                        "function", subject=subject, location=loc(node))
    return rep


def check_safety(bodies: list[RenderedBody]) -> AnalysisReport:
    rep = AnalysisReport()
    for rb in bodies:
        if rb.tree is not None:
            rep.extend(check_body(rb))
    return rep
