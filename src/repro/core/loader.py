"""UPD loading (paper §3.2 ⑤ "Input Description").

The paper uses YAML with *"a single YAML document, enclosed by three dashes at
the beginning and three dots at the end, for every primitive"* — i.e.
multi-document streams per group file.  Targets are one document per file.
"""

from __future__ import annotations

import os
from pathlib import Path

import yaml

DEFAULT_UPD_ROOT = Path(__file__).resolve().parent.parent / "tsl_data"


def _upd_roots(extra: tuple[str, ...] = ()) -> list[Path]:
    roots = [DEFAULT_UPD_ROOT]
    env = os.environ.get("REPRO_TSL_UPD_PATH", "")
    roots += [Path(p) for p in env.split(os.pathsep) if p]
    roots += [Path(p) for p in extra]
    return roots


def load_raw_targets(extra_paths: tuple[str, ...] = ()) -> list[dict]:
    docs: list[dict] = []
    for root in _upd_roots(extra_paths):
        tdir = root / "targets"
        if not tdir.is_dir():
            continue
        for f in sorted(tdir.glob("*.yaml")):
            for doc in yaml.safe_load_all(f.read_text()):
                if doc is None:
                    continue
                doc.setdefault("__source__", str(f))
                docs.append(doc)
    return docs


def load_raw_primitives(extra_paths: tuple[str, ...] = ()) -> list[dict]:
    docs: list[dict] = []
    for root in _upd_roots(extra_paths):
        pdir = root / "primitives"
        if not pdir.is_dir():
            continue
        for f in sorted(pdir.glob("*.yaml")):
            group_default = f.stem
            for doc in yaml.safe_load_all(f.read_text()):
                if doc is None:
                    continue
                doc.setdefault("group", group_default)
                doc.setdefault("__source__", str(f))
                docs.append(doc)
    return docs


def upd_fingerprint(extra_paths: tuple[str, ...] = ()) -> str:
    """Content hash over all UPD + template files — cache key for generation."""
    import hashlib

    h = hashlib.sha256()
    files: list[Path] = []
    for root in _upd_roots(extra_paths):
        if root.is_dir():
            files += sorted(root.rglob("*.yaml"))
    tmpl = Path(__file__).resolve().parent / "templates"
    if tmpl.is_dir():
        files += sorted(tmpl.rglob("*.j2"))
    # generator source itself participates: a generator change must invalidate
    core = Path(__file__).resolve().parent
    files += sorted(core.glob("*.py"))
    for f in files:
        h.update(str(f).encode())
        h.update(f.read_bytes())
    return h.hexdigest()[:16]
