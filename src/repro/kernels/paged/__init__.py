from .ref import page_read, page_write  # noqa: F401
