"""Distribution layer: pure sharding rules + gradient compression.

``sharding`` holds mesh-aware PartitionSpec rules (pure functions of shapes
and names, so they are unit-testable without devices); ``compression`` holds
int8 gradient compression: the train step round-trips gradients through the
quantizer, and an ``ErrorFeedback`` helper is available for residual carry
(not yet threaded through train_state — the biased scheme is the current
default).
"""

from . import compression, sharding  # noqa: F401
