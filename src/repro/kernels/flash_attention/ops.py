"""Public wrapper: pads sequence dims to block multiples, restores shape.

Differentiable end-to-end in Pallas: the forward kernel emits per-row
logsumexp residuals and the backward runs dedicated recomputation kernels —
a q-tiled pass for dq and a k-tiled pass for dk/dv (GQA head groups reduced
outside the kernel in f32). The ``custom_vjp`` therefore saves only
O(Sq)-per-head state (inputs + out + lse); the (Sq, Sk) attention matrix is
never materialized on the training path. ``flash_attention_vjp`` exposes the
same backward directly for the UPD ``flash_attention_bwd`` primitive, where
block sizes are owned by the §4.2 bench-selection machinery.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import pad_to
from . import kernel, ref


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5))
def _fa(causal, scale, kv_len, block_q, block_k, interpret, q, k, v):
    qp, _ = pad_to(q, 2, block_q)
    kp, _ = pad_to(k, 2, block_k)
    vp, _ = pad_to(v, 2, block_k)
    out = kernel.flash_attention_4d(
        qp, kp, vp, causal=causal, scale=scale, kv_len=kv_len,
        q_offset=kv_len - q.shape[2], block_q=block_q, block_k=block_k,
        interpret=interpret)
    return out[:, :, :q.shape[2]]


def _fa_fwd(causal, scale, kv_len, block_q, block_k, interpret, q, k, v):
    qp, _ = pad_to(q, 2, block_q)
    kp, _ = pad_to(k, 2, block_k)
    vp, _ = pad_to(v, 2, block_k)
    out, lse = kernel.flash_attention_fwd_4d(
        qp, kp, vp, causal=causal, scale=scale, kv_len=kv_len,
        q_offset=kv_len - q.shape[2], block_q=block_q, block_k=block_k,
        interpret=interpret)
    sq = q.shape[2]
    # residuals are O(Sq) per head: inputs + out + logsumexp — no S×S tensor
    return out[:, :, :sq], (q, k, v, out[:, :, :sq], lse[:, :, :sq])


def _fa_bwd_kernels(q, k, v, g, out, lse, *, causal, scale, kv_len,
                    block_q, block_k, interpret):
    """Shared backward body: pad to block multiples, run dq + dk/dv kernels,
    reduce GQA head groups, slice back to logical shapes."""
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    group = h // kh
    q_offset = kv_len - sq
    delta = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    qp, _ = pad_to(q, 2, block_q)
    gp, _ = pad_to(g.astype(q.dtype), 2, block_q)
    lsep, _ = pad_to(lse, 2, block_q)
    deltap, _ = pad_to(delta, 2, block_q)
    kp, _ = pad_to(k, 2, block_k)
    vp, _ = pad_to(v, 2, block_k)
    common = dict(causal=causal, scale=scale, kv_len=kv_len, q_offset=q_offset,
                  block_q=block_q, block_k=block_k, interpret=interpret)
    dq = kernel.flash_attention_bwd_dq_4d(qp, kp, vp, gp, lsep, deltap, **common)
    dkf, dvf = kernel.flash_attention_bwd_dkv_4d(qp, kp, vp, gp, lsep, deltap,
                                                 **common)
    skp = dkf.shape[2]
    dk = dkf.reshape(b, kh, group, skp, d).sum(2)[:, :, :sk].astype(k.dtype)
    dv = dvf.reshape(b, kh, group, skp, d).sum(2)[:, :, :sk].astype(v.dtype)
    return dq[:, :, :sq], dk, dv


def _fa_bwd(causal, scale, kv_len, block_q, block_k, interpret, res, g):
    q, k, v, out, lse = res
    return _fa_bwd_kernels(q, k, v, g, out, lse, causal=causal, scale=scale,
                           kv_len=kv_len, block_q=block_q, block_k=block_k,
                           interpret=interpret)


_fa.defvjp(_fa_fwd, _fa_bwd)


@partial(jax.jit, static_argnames=("causal", "scale", "kv_len", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    kv_len: int | None = None, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """Flash attention with GQA. q (B,H,Sq,D), k/v (B,KH,Sk,D) -> (B,H,Sq,D).

    Padded q rows are garbage and sliced off; padded k columns are masked by
    kv_len inside the kernel; causal alignment uses the logical sq."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(128, sk))
    kv_len = kv_len if kv_len is not None else sk
    return _fa(causal, scale, kv_len, bq, bk, interpret, q, k, v)


@partial(jax.jit, static_argnames=("causal", "scale", "kv_len", "block_q",
                                   "block_k", "interpret"))
def flash_attention_vjp(q, k, v, g, *, causal: bool = True,
                        scale: float | None = None, kv_len: int | None = None,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False):
    """Standalone (dq, dk, dv) for cotangent ``g`` — the UPD
    ``flash_attention_bwd`` entry point. Re-runs the residual-emitting
    forward, then the recomputation backward kernels; peak memory stays
    O(Sq + Sk) per head for any sequence length."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(128, sk))
    kv_len = kv_len if kv_len is not None else sk
    sc = scale if scale is not None else 1.0 / (d ** 0.5)
    _, (q, k, v, out, lse) = _fa_fwd(causal, sc, kv_len, bq, bk, interpret,
                                     q, k, v)
    return _fa_bwd_kernels(q, k, v, g, out, lse, causal=causal, scale=sc,
                           kv_len=kv_len, block_q=bq, block_k=bk,
                           interpret=interpret)


__all__ = ["flash_attention", "flash_attention_vjp", "ref"]
