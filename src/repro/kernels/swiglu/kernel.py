"""Pallas TPU kernel: fused SwiGLU (silu(gate) * up).

Element-wise fusion: one VMEM round trip instead of three (silu read+write,
multiply read+read+write). Memory-bound by construction — the win is purely
the 2.5x HBM traffic reduction, which the §Roofline memory term sees.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _swiglu_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (g * jax.nn.sigmoid(g) * u).astype(o_ref.dtype)


def swiglu_2d(gate, up, *, block_rows: int = 256, interpret: bool = False):
    rows, d = gate.shape
    bm = min(block_rows, rows)
    assert rows % bm == 0
    return pl.pallas_call(
        _swiglu_kernel,
        grid=(rows // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(gate.shape, gate.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name="tsl_swiglu",
    )(gate, up)
