"""Schema DSL + validation for the UPD (paper §3.2 ⑥ "Schema Description").

The paper: *"Every entry has a name and an expected fundamental (e.g., string
or a list of strings) or composed type. [...] we distinguish between two types
of entries within a composed type: mandatory entries must be specified [...]
optional entries may or may not be specified [...] a default value is defined
for every optional entry. We also allow arbitrary additional fields beyond the
ones specified by the schema."*

YAML has no schema DSL, so — like the paper — we implement validation
ourselves.  ``Schema.apply`` returns the *enriched* document (defaults filled
in) plus error/warning lists; it never throws, so the validation GPO can
surface all problems at once (paper: "errors are prompted to the user").
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

# ---------------------------------------------------------------------------
# fundamental types

_FUNDAMENTAL: dict[str, Callable[[Any], bool]] = {
    "str": lambda v: isinstance(v, str),
    "code": lambda v: isinstance(v, str),          # code block (rendered stage-1)
    "int": lambda v: isinstance(v, int) and not isinstance(v, bool),
    "float": lambda v: isinstance(v, (int, float)) and not isinstance(v, bool),
    "bool": lambda v: isinstance(v, bool),
    "list[str]": lambda v: isinstance(v, list) and all(isinstance(x, str) for x in v),
    "list[int]": lambda v: isinstance(v, list)
    and all(isinstance(x, int) and not isinstance(x, bool) for x in v),
    "dict": lambda v: isinstance(v, dict),
    "any": lambda v: True,
}


@dataclass(frozen=True)
class Entry:
    """One schema entry (paper ⑥): fundamental or composed, mandatory or optional."""

    name: str
    type: str = "str"                    # key into _FUNDAMENTAL, or "composed"/"list[composed]"
    mandatory: bool = False
    default: Any = None                  # required for optional entries (paper)
    child: "Schema | None" = None        # for composed / list[composed]
    choices: tuple[str, ...] | None = None
    description: str = ""

    def __post_init__(self):
        if self.type in ("composed", "list[composed]") and self.child is None:
            raise ValueError(f"entry {self.name!r}: composed type requires child schema")
        if self.type not in _FUNDAMENTAL and self.type not in ("composed", "list[composed]"):
            raise ValueError(f"entry {self.name!r}: unknown type {self.type!r}")


@dataclass(frozen=True)
class Schema:
    name: str
    entries: tuple[Entry, ...]
    allow_extra: bool = True             # paper: arbitrary additional fields allowed

    def entry_names(self) -> set[str]:
        return {e.name for e in self.entries}

    # -- validation ---------------------------------------------------------

    def apply(self, doc: Any, *, path: str = "") -> tuple[dict, list[str], list[str]]:
        """Validate + enrich ``doc``. Returns (enriched, errors, warnings)."""
        errors: list[str] = []
        warnings: list[str] = []
        loc = path or self.name
        if not isinstance(doc, dict):
            return {}, [f"{loc}: expected a mapping, got {type(doc).__name__}"], warnings

        out: dict[str, Any] = {}
        for e in self.entries:
            p = f"{loc}.{e.name}"
            if e.name not in doc:
                if e.mandatory:
                    errors.append(f"{p}: mandatory entry missing")
                else:
                    out[e.name] = _copy_default(e.default)
                continue
            v = doc[e.name]
            if e.type == "composed":
                sub, errs, warns = e.child.apply(v, path=p)
                out[e.name] = sub
                errors += errs
                warnings += warns
            elif e.type == "list[composed]":
                if not isinstance(v, list):
                    errors.append(f"{p}: expected a list, got {type(v).__name__}")
                    continue
                subs = []
                for i, item in enumerate(v):
                    sub, errs, warns = e.child.apply(item, path=f"{p}[{i}]")
                    subs.append(sub)
                    errors += errs
                    warnings += warns
                out[e.name] = subs
            else:
                if not _FUNDAMENTAL[e.type](v):
                    errors.append(
                        f"{p}: expected {e.type}, got {type(v).__name__} ({v!r})"
                    )
                    continue
                if e.choices is not None and v not in e.choices:
                    errors.append(f"{p}: {v!r} not in allowed choices {sorted(e.choices)}")
                    continue
                out[e.name] = v

        # arbitrary additional fields (paper ⑥): pass through, but surface them
        for k, v in doc.items():
            if k not in self.entry_names():
                if self.allow_extra:
                    out[k] = v
                    warnings.append(f"{loc}.{k}: extra field passed through (not in schema)")
                else:
                    errors.append(f"{loc}.{k}: unknown field")
        return out, errors, warnings


def _copy_default(v: Any) -> Any:
    if isinstance(v, (list, dict)):
        import copy

        return copy.deepcopy(v)
    return v


# ---------------------------------------------------------------------------
# concrete schemas — inferred bottom-up from the templates (paper ⑥, footnote 4)

PARAM_SCHEMA = Schema(
    "parameter",
    (
        Entry("name", "str", mandatory=True),
        Entry("ctype", "str", default="register"),
        Entry("default", "any", default=None),
        Entry("attributes", "list[str]", default=[]),
        Entry("description", "str", default=""),
    ),
)

DEFINITION_SCHEMA = Schema(
    "definition",
    (
        # str, or list[str] (compact multi-target definition; expanded by the
        # validation GPO into one ImplDef per target)
        Entry("target_extension", "any", mandatory=True),
        Entry("ctype", "list[str]", mandatory=True),
        Entry("lscpu_flags", "list[str]", default=[]),       # paper's key name, kept verbatim
        Entry("implementation", "code", mandatory=True),
        Entry("is_native", "bool", default=True),            # paper §3.2
        Entry("helpers", "code", default=""),
        Entry("cost", "dict", default={}),
        Entry("note", "str", default=""),
        # per-definition analysis suppression: lint: {suppress: [TSL0xx, ...]}
        Entry("lint", "dict", default={}),
    ),
)

TEST_SCHEMA = Schema(
    "test",
    (
        Entry("name", "str", mandatory=True),
        Entry("implementation", "code", mandatory=True),
        Entry("requires", "list[str]", default=[]),
    ),
)

PRIMITIVE_SCHEMA = Schema(
    "primitive",
    (
        Entry("primitive_name", "str", mandatory=True),
        Entry("group", "str", default="misc"),
        Entry("brief", "str", default=""),
        Entry("parameters", "list[composed]", default=[], child=PARAM_SCHEMA),
        Entry(
            "returns",
            "composed",
            default={"ctype": "register"},
            child=Schema("returns", (Entry("ctype", "str", default="register"),)),
        ),
        Entry("definitions", "list[composed]", mandatory=True, child=DEFINITION_SCHEMA),
        Entry("testing", "list[composed]", default=[], child=TEST_SCHEMA),
        # dispatch: "auto" = dtype of first register param, "none" = single
        # specialization (default_ctype), or an explicit parameter name.
        Entry("dispatch", "str", default="auto"),
        # shape-symbol vocabulary the cost: formulas may reference — the
        # keyword set callers pass to the generated cost(); checked by
        # TSL-Check (TSL012/TSL013).
        Entry("cost_shapes", "list[str]", default=[]),
        # primitive-wide analysis suppression: lint: {suppress: [TSL0xx, ...]}
        Entry("lint", "dict", default={}),
        # bench: sample-input factory enabling benchmark-driven adaptive
        # variant selection (beyond-paper, paper §4.2 future work).
        Entry(
            "bench",
            "composed",
            default=None,
            child=Schema(
                "bench",
                (
                    Entry("setup", "code", mandatory=True),
                    Entry("n_iter", "int", default=30),
                ),
            ),
        ),
    ),
)

TARGET_SCHEMA = Schema(
    "target",
    (
        Entry("name", "str", mandatory=True),
        Entry("vendor", "str", default="unknown"),
        Entry("lscpu_flags", "list[str]", mandatory=True),
        Entry("ctypes", "list[str]", mandatory=True),
        Entry("default_ctype", "str", default="float32"),
        Entry("lanes", "int", default=128),
        Entry("sublanes", "int", default=8),
        Entry("mxu", "list[int]", default=[128, 128]),
        Entry("vmem_bytes", "int", default=16 * 2**20),
        Entry("hbm_bytes", "int", default=16 * 2**30),
        Entry("peak_flops_bf16", "float", default=197e12),
        Entry("hbm_bw", "float", default=819e9),
        Entry("ici_bw", "float", default=50e9),
        Entry("ici_links", "int", default=3),
        Entry("interpret", "bool", default=False),
        Entry("runs_on_host", "bool", default=True),
        Entry("dtype_map", "dict", default={}),
        Entry("description", "str", default=""),
    ),
)
